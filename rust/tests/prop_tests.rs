//! Property-based tests over the paper's invariants (in-tree framework —
//! see `quiver::testutil`), plus the differential fuzz harnesses: a
//! seeded structure-aware generator drives random pipeline
//! configurations `(d, s, distribution, level set)` through
//! encode → decode and solver-vs-exhaustive comparisons. The fuzz
//! iteration count is a fixed CI budget, overridable with
//! `QUIVER_FUZZ_ITERS=<n>` for longer local soak runs.

use quiver::avq::{self, Prefix, SolverKind};
use quiver::dist::Dist;
use quiver::metrics::sum_variances;
use quiver::sq;
use quiver::testutil::{forall, forall_vec, Gen};
use quiver::util::approx_eq;

/// Lemma 5.2: the interval cost satisfies the quadrangle inequality —
/// random (possibly weighted) inputs, random index quadruples.
#[test]
fn prop_quadrangle_inequality_c_and_c2() {
    forall(60, 0xA1, |g: &mut Gen, _| {
        let ys = g.sorted_vec(8..64);
        let n = ys.len();
        let p = if g.bool() {
            let ws = g.weights(n, 9);
            Prefix::weighted(&ys, &ws)
        } else {
            Prefix::unweighted(&ys)
        };
        for _ in 0..50 {
            let mut ix = [
                g.usize_in(0..n),
                g.usize_in(0..n),
                g.usize_in(0..n),
                g.usize_in(0..n),
            ];
            ix.sort_unstable();
            let [a, b, c, d] = ix;
            let (l1, r1) = (p.cost(a, c) + p.cost(b, d), p.cost(a, d) + p.cost(b, c));
            if l1 > r1 + 1e-9 * r1.abs().max(1.0) {
                return Err(format!("C QI violated at {ix:?}: {l1} > {r1}"));
            }
            let (l2, r2) = (p.cost2(a, c) + p.cost2(b, d), p.cost2(a, d) + p.cost2(b, c));
            if l2 > r2 + 1e-9 * r2.abs().max(1.0) {
                return Err(format!("C2 QI violated at {ix:?}: {l2} > {r2}"));
            }
        }
        Ok(())
    });
}

/// Proposition 4.1: the DP argmin is monotone in j for any valid D row.
#[test]
fn prop_argmin_monotone() {
    forall(30, 0xA2, |g: &mut Gen, _| {
        let ys = g.sorted_vec(10..80);
        let n = ys.len();
        let p = Prefix::unweighted(&ys);
        // A valid previous row: MSE[2][k] = C[0,k].
        let prev: Vec<f64> = (0..n).map(|k| p.cost(0, k)).collect();
        let mut last = 0usize;
        for j in 0..n {
            let mut best = f64::INFINITY;
            let mut arg = 0usize;
            for k in 0..=j {
                let v = prev[k] + p.cost(k, j);
                if v < best {
                    best = v;
                    arg = k;
                }
            }
            if arg < last {
                return Err(format!("argmin regressed at j={j}: {arg} < {last}"));
            }
            last = arg;
        }
        Ok(())
    });
}

/// The headline cross-check: every exact solver returns the same optimal
/// MSE as the exhaustive oracle, on every paper distribution, weighted or
/// not, and the traceback reproduces the claimed objective.
#[test]
fn prop_all_solvers_agree_with_oracle() {
    forall(60, 0xA3, |g: &mut Gen, _| {
        let ys = {
            let mut v = g.sorted_vec(5..13);
            // Occasionally inject duplicates to stress tie handling.
            if g.bool() && v.len() >= 4 {
                let dup = v[1];
                v[2] = dup;
            }
            v.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
            v
        };
        let n = ys.len();
        let p = if g.bool() {
            Prefix::weighted(&ys, &g.weights(n, 6))
        } else {
            Prefix::unweighted(&ys)
        };
        let s = g.usize_in(2..n.max(3));
        let oracle = avq::solve(&p, s, SolverKind::Exhaustive).map_err(|e| e.to_string())?;
        for kind in [SolverKind::ZipMl, SolverKind::BinSearch, SolverKind::Quiver, SolverKind::QuiverAccel] {
            let sol = avq::solve(&p, s, kind).map_err(|e| e.to_string())?;
            if !approx_eq(sol.mse, oracle.mse, 1e-9, 1e-12) {
                return Err(format!(
                    "{} disagrees: {} vs oracle {} (d={n}, s={s})",
                    kind.name(),
                    sol.mse,
                    oracle.mse
                ));
            }
            let recomputed = sol.recompute_mse(&p);
            if !approx_eq(recomputed, sol.mse, 1e-9, 1e-12) {
                return Err(format!(
                    "{} traceback mismatch: {} vs {}",
                    kind.name(),
                    recomputed,
                    sol.mse
                ));
            }
        }
        Ok(())
    });
}

/// Cross-solver agreement over the distribution families: all five
/// [`SolverKind`]s return the same MSE as the `Exhaustive` oracle (within
/// 1e-9) on small inputs (d ≤ 14, s ≤ 5) drawn from every paper
/// distribution across several seeds, and every solver's traceback
/// reproduces its reported objective.
#[test]
fn prop_five_solvers_agree_across_dist_families() {
    for (di, (name, dist)) in Dist::paper_suite().into_iter().enumerate() {
        for seed in 0..6u64 {
            for d in [5usize, 8, 11, 14] {
                let xs = dist.sample_sorted(d, 300 + 31 * di as u64 + seed);
                let p = Prefix::unweighted(&xs);
                let s_max = 5usize.min(d - 1);
                for s in 2..=s_max {
                    let oracle = avq::solve(&p, s, SolverKind::Exhaustive).unwrap();
                    for kind in SolverKind::ALL {
                        let sol = avq::solve(&p, s, kind).unwrap();
                        assert!(
                            approx_eq(sol.mse, oracle.mse, 1e-9, 1e-12),
                            "{name} seed={seed} d={d} s={s}: {} returned {} vs oracle {}",
                            kind.name(),
                            sol.mse,
                            oracle.mse
                        );
                        assert!(
                            approx_eq(sol.recompute_mse(&p), sol.mse, 1e-9, 1e-12),
                            "{name} seed={seed} d={d} s={s}: {} traceback {} vs reported {}",
                            kind.name(),
                            sol.recompute_mse(&p),
                            sol.mse
                        );
                    }
                }
            }
        }
    }
}

/// Optimal MSE is non-increasing in the budget s.
#[test]
fn prop_mse_monotone_in_s() {
    forall(25, 0xA4, |g: &mut Gen, _| {
        let ys = g.sorted_vec(20..200);
        let p = Prefix::unweighted(&ys);
        let mut prev = f64::INFINITY;
        for s in 2..10 {
            let sol = avq::solve(&p, s, SolverKind::QuiverAccel).map_err(|e| e.to_string())?;
            if sol.mse > prev + 1e-9 * prev.max(1.0) {
                return Err(format!("MSE increased at s={s}: {} > {prev}", sol.mse));
            }
            prev = sol.mse;
        }
        Ok(())
    });
}

/// The solver-reported objective equals the independently computed sum of
/// variances of its Q over the input.
#[test]
fn prop_solution_mse_matches_metric() {
    forall(30, 0xA5, |g: &mut Gen, _| {
        let ys = g.sorted_vec(10..300);
        let p = Prefix::unweighted(&ys);
        let s = g.usize_in(2..9);
        let sol = avq::solve(&p, s, SolverKind::Quiver).map_err(|e| e.to_string())?;
        let direct = sum_variances(&ys, &sol.q);
        if !approx_eq(direct, sol.mse, 1e-9, 1e-9) {
            return Err(format!("metric {direct} vs solver {}", sol.mse));
        }
        Ok(())
    });
}

/// Histogram path: mass conservation, covering Q, and the §6 bound
/// relative to the histogram optimum.
#[test]
fn prop_histogram_invariants() {
    use quiver::avq::histogram::{solve_hist, theory_bound, GridHistogram, HistConfig};
    use quiver::util::rng::Xoshiro256pp;
    forall(25, 0xA6, |g: &mut Gen, case| {
        let xs = g.vec_f64(50..2000, -5.0..20.0);
        let m = g.usize_in(2..500);
        let mut rng = Xoshiro256pp::seed_from_u64(case);
        let h = GridHistogram::build(&xs, m, &mut rng).map_err(|e| e.to_string())?;
        if h.total() != xs.len() as f64 {
            return Err(format!("mass {} != d {}", h.total(), xs.len()));
        }
        let s = g.usize_in(2..9);
        let sol = solve_hist(&xs, s, &HistConfig { m, inner: SolverKind::QuiverAccel, seed: case })
            .map_err(|e| e.to_string())?;
        let (lo, hi) = xs
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h2), &x| (l.min(x), h2.max(x)));
        if sol.q[0] > lo || *sol.q.last().unwrap() < hi {
            return Err("hist Q does not cover the input".into());
        }
        // True error respects the paper's bound (vs the histogram optimum).
        let mut sorted = xs.clone();
        sorted.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let err = sum_variances(&sorted, &sol.q);
        let norm2: f64 = xs.iter().map(|x| x * x).sum();
        let bound = theory_bound(sol.mse, xs.len(), m, norm2);
        if err > bound * (1.0 + 1e-9) + 1e-9 {
            return Err(format!("error {err} exceeds §6 bound {bound} (m={m})"));
        }
        Ok(())
    });
}

/// Bit-packing codec: lossless roundtrip for arbitrary (idx, qs).
#[test]
fn prop_codec_roundtrip() {
    forall(60, 0xA7, |g: &mut Gen, _| {
        let s = g.usize_in(1..70);
        let d = g.usize_in(0..3000);
        let qs: Vec<f64> = (0..s).map(|i| i as f64 * 0.25).collect();
        let idx: Vec<u32> = (0..d).map(|_| g.usize_in(0..s) as u32).collect();
        let c = sq::encode(&idx, &qs);
        let bytes = c.to_bytes();
        let c2 = sq::CompressedVec::from_bytes(&bytes).ok_or("parse failed")?;
        let (idx2, qs2) = sq::decode(&c2);
        if idx2 != idx || qs2 != qs {
            return Err("roundtrip mismatch".into());
        }
        Ok(())
    });
}

/// Unbiased SQ: for any covering Q, decoded estimates stay within the
/// bracketing values of each coordinate.
#[test]
fn prop_sq_outputs_bracket() {
    forall(40, 0xA8, |g: &mut Gen, case| {
        use quiver::util::rng::Xoshiro256pp;
        let xs = g.vec_f64(1..500, -3.0..3.0);
        let (lo, hi) = xs
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &x| (l.min(x), h.max(x)));
        let s = g.usize_in(2..10);
        let mut qs: Vec<f64> = (0..s).map(|_| g.f64_in(lo..hi + 1e-9)).collect();
        qs[0] = lo;
        qs[s - 1] = hi;
        qs.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let mut rng = Xoshiro256pp::seed_from_u64(case);
        let idx = sq::quantize(&xs, &qs, &mut rng);
        for (&x, &i) in xs.iter().zip(&idx) {
            let v = qs[i as usize];
            // v must be a neighbour of x in qs.
            let pos = qs.partition_point(|&q| q < x);
            let lo_q = qs[pos.saturating_sub(1)];
            let hi_q = qs[pos.min(s - 1)];
            if (v - lo_q).abs() > 1e-12 && (v - hi_q).abs() > 1e-12 {
                return Err(format!("x={x} quantized to non-neighbour {v}"));
            }
        }
        Ok(())
    });
}

/// Shrinking smoke test: a deliberately strict property on vectors finds
/// minimal counterexamples (framework self-check at integration level).
#[test]
fn prop_vec_shrinking_framework() {
    // Property that always holds — must not panic.
    forall_vec(
        20,
        0xA9,
        |g| g.vec_f64(0..100, -1.0..1.0),
        |v| {
            if v.iter().all(|x| x.abs() <= 1.0) {
                Ok(())
            } else {
                Err("range".into())
            }
        },
    );
}

/// Shard decomposition (`coordinator::shard`): for random dimensions, bin
/// counts and shard counts — including shards ≫ chunks — the sharded
/// histogram build is bitwise-identical to the single-node build.
#[test]
fn prop_sharded_build_matches_single_node() {
    use quiver::avq::histogram::GridHistogram;
    use quiver::coordinator::shard::build_sharded;
    use quiver::util::rng::Xoshiro256pp;
    forall(10, 0xB7, |g: &mut Gen, case| {
        let d = g.usize_in(1..2 * quiver::par::CHUNK + 999);
        let m = g.usize_in(1..300);
        let shards = g.usize_in(1..12);
        let xs = Dist::LogNormal { mu: 0.0, sigma: 1.0 }.sample_vec(d, 7000 + case);
        let seed = g.u64();
        let mut r1 = Xoshiro256pp::seed_from_u64(seed);
        let want = GridHistogram::build(&xs, m, &mut r1).unwrap();
        let mut r2 = Xoshiro256pp::seed_from_u64(seed);
        let got = build_sharded(&xs, m, &mut r2, shards).unwrap();
        if got.weights != want.weights
            || got.grid != want.grid
            || got.norm2_sq.to_bits() != want.norm2_sq.to_bits()
        {
            return Err(format!("shard mismatch d={d} m={m} shards={shards}"));
        }
        // Both consumed exactly one draw.
        if r1.next_u64() != r2.next_u64() {
            return Err("stream advance diverged".into());
        }
        Ok(())
    });
}

/// Fuzz the wire decoders: arbitrary bytes must never panic — only return
/// errors (the server parses untrusted input).
#[test]
fn prop_decoders_never_panic_on_garbage() {
    use quiver::coordinator::protocol::Msg;
    forall(300, 0xAA, |g: &mut Gen, _| {
        let len = g.usize_in(0..512);
        let bytes: Vec<u8> = (0..len).map(|_| g.usize_in(0..256) as u8).collect();
        let _ = Msg::from_body(&bytes); // must not panic
        let _ = sq::CompressedVec::from_bytes(&bytes); // must not panic
        Ok(())
    });
}

/// Iteration budget for the differential fuzz harnesses below: the fixed
/// CI default unless `QUIVER_FUZZ_ITERS` overrides it (soak runs).
fn fuzz_iters(default: usize) -> usize {
    std::env::var("QUIVER_FUZZ_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Differential fuzz, pipeline half: a structure-aware draw of dimension
/// (occasionally straddling a chunk boundary), distribution family, and
/// level set (solver-produced or a synthetic covering grid, including the
/// byte-aligned `s = 256` codec width) is round-tripped through
/// quantize → encode → wire bytes → decode → dequantize. The index
/// stream and level table must be lossless, and every reconstructed
/// coordinate must be one of its input's two bracketing levels. Failures
/// print the case seed for replay.
#[test]
fn fuzz_pipeline_roundtrip_structured() {
    use quiver::avq::histogram::{solve_hist, HistConfig};
    use quiver::util::rng::Xoshiro256pp;
    forall(fuzz_iters(150), 0xF0, |g: &mut Gen, case| {
        let d = if g.usize_in(0..10) == 0 {
            g.usize_in(quiver::par::CHUNK - 2..quiver::par::CHUNK + 3)
        } else {
            g.usize_in(1..2000)
        };
        let suite = Dist::paper_suite();
        let (_, dist) = suite[g.usize_in(0..suite.len())];
        let xs = dist.sample_vec(d, g.u64());
        let (lo, hi) = xs
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &x| (l.min(x), h.max(x)));
        let qs: Vec<f64> = if lo == hi {
            vec![lo]
        } else if g.bool() {
            // Solver-produced levels (small budgets; the realistic shape).
            let s = g.usize_in(2..9);
            solve_hist(&xs, s, &HistConfig::fixed(g.usize_in(16..512)))
                .map_err(|e| e.to_string())?
                .q
        } else {
            // Synthetic covering grid; half the time the u8 fast-path width.
            let s = if g.bool() { 256 } else { g.usize_in(2..70) };
            let mut qs: Vec<f64> = (0..s).map(|_| g.f64_in(lo..hi)).collect();
            qs[0] = lo;
            qs[s - 1] = hi;
            qs.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
            qs
        };
        let mut rng = Xoshiro256pp::seed_from_u64(case);
        let idx = sq::quantize(&xs, &qs, &mut rng);
        let c = sq::encode(&idx, &qs);
        let c2 = sq::CompressedVec::from_bytes(&c.to_bytes()).ok_or("wire parse failed")?;
        if c2 != c {
            return Err("wire roundtrip changed the record".into());
        }
        let (idx2, qs2) = sq::decode(&c2);
        if idx2 != idx {
            return Err(format!("index stream not lossless (d={d}, s={})", qs.len()));
        }
        if qs2.iter().zip(&qs).any(|(a, b)| a.to_bits() != b.to_bits()) {
            return Err("level table not lossless".into());
        }
        let vals = sq::dequantize(&idx2, &qs2);
        for (i, (&x, &v)) in xs.iter().zip(&vals).enumerate() {
            let pos = qs.partition_point(|&q| q < x);
            let lo_q = qs[pos.saturating_sub(1)];
            let hi_q = qs[pos.min(qs.len() - 1)];
            if v.to_bits() != lo_q.to_bits() && v.to_bits() != hi_q.to_bits() {
                return Err(format!("coord {i}: x={x} decoded to non-neighbour {v}"));
            }
        }
        Ok(())
    });
}

/// Differential fuzz, solver half: random small instances — distribution
/// family, optional exact duplicates, optional integral weights, random
/// budget — are solved by every [`SolverKind`] and checked against the
/// exhaustive oracle, with the traceback reproducing each reported
/// objective.
#[test]
fn fuzz_solvers_vs_exhaustive_structured() {
    forall(fuzz_iters(100), 0xF1, |g: &mut Gen, _| {
        let suite = Dist::paper_suite();
        let (_, dist) = suite[g.usize_in(0..suite.len())];
        let d = g.usize_in(4..15);
        let mut ys = dist.sample_sorted(d, g.u64());
        let p = if g.bool() {
            // Weighted path wants distinct support.
            ys.dedup();
            Prefix::weighted(&ys, &g.weights(ys.len(), 7))
        } else {
            if g.bool() {
                ys[2] = ys[1]; // exact duplicate to stress tie handling
            }
            Prefix::unweighted(&ys)
        };
        if ys.len() < 4 {
            return Ok(()); // dedup collapsed the draw below solvable sizes
        }
        let s = g.usize_in(2..ys.len());
        let oracle = avq::solve(&p, s, SolverKind::Exhaustive).map_err(|e| e.to_string())?;
        for kind in SolverKind::ALL {
            let sol = avq::solve(&p, s, kind).map_err(|e| e.to_string())?;
            if !approx_eq(sol.mse, oracle.mse, 1e-9, 1e-12) {
                return Err(format!(
                    "{}: {} vs oracle {} (d={}, s={s})",
                    kind.name(),
                    sol.mse,
                    oracle.mse,
                    ys.len()
                ));
            }
            if !approx_eq(sol.recompute_mse(&p), sol.mse, 1e-9, 1e-12) {
                return Err(format!("{} traceback mismatch at s={s}", kind.name()));
            }
        }
        Ok(())
    });
}

/// Differential fuzz, ingestion half: a structure-aware draw of ingest
/// shape — the chunk-boundary edge dimensions 1, CHUNK−1, CHUNK, CHUNK+1,
/// a random single-chunk stream, or a ragged multi-chunk stream — with a
/// random distribution, grid size, budget, task id, and a seeded random
/// chunk arrival permutation, checked bitwise against the monolithic
/// reference. Failures print the case seed for replay.
#[test]
fn fuzz_ingest_shapes_and_arrival_orders_match_monolithic() {
    use quiver::coordinator::ingest::{self, IngestConfig};
    use quiver::util::rng::Xoshiro256pp;
    let chunk = quiver::par::CHUNK;
    forall(fuzz_iters(24), 0xF2, |g: &mut Gen, case| {
        let cfg = IngestConfig { m: g.usize_in(8..128), ..Default::default() };
        let d = match g.usize_in(0..6) {
            0 => 1,
            1 => chunk - 1,
            2 => chunk,
            3 => chunk + 1,
            4 => g.usize_in(1..chunk),                    // single chunk
            _ => g.usize_in(chunk + 1..2 * chunk + 1000), // ragged multi-chunk
        };
        let suite = Dist::paper_suite();
        let (_, dist) = suite[g.usize_in(0..suite.len())];
        let data: Vec<f32> =
            dist.sample_vec(d, g.u64()).into_iter().map(|x| x as f32).collect();
        let task_id = g.u64();
        let s = g.usize_in(1..40) as u32;
        let (want, _) =
            ingest::monolithic_reference(&data, s, &cfg, task_id).map_err(|e| e.to_string())?;
        let mut order: Vec<u64> = (0..d.div_ceil(chunk) as u64).collect();
        Xoshiro256pp::seed_from_u64(case).shuffle(&mut order);
        let (got, _) = ingest::ingest_local(&data, s, &cfg, task_id, Some(&order))
            .map_err(|e| e.to_string())?;
        if got != want {
            return Err(format!("ingest mismatch d={d} order={order:?}"));
        }
        Ok(())
    });
}

/// Ingest protocol abuse: empty streams are a typed open-time rejection,
/// and a chunk split at any point other than the fixed CHUNK grid is a
/// typed `WrongChunkLen` rejection — chunk boundaries are part of the
/// determinism contract (DESIGN.md rule 2), so a misaligned split must
/// never fold.
#[test]
fn fuzz_ingest_misaligned_splits_are_rejected_typed() {
    use quiver::coordinator::ingest::{IngestConfig, IngestConn, IngestError, IngestEvent};
    let chunk = quiver::par::CHUNK;
    forall(fuzz_iters(40), 0xF3, |g: &mut Gen, _| {
        let mut conn = IngestConn::new(IngestConfig { m: 32, ..Default::default() });
        match conn.open(7, 0, 4, 0.0, 1.0) {
            IngestEvent::Reject(7, IngestError::EmptyInput) => {}
            other => return Err(format!("empty open: {other:?}")),
        }
        // A multi-chunk task: chunk 0 must carry exactly CHUNK elements.
        let d = g.usize_in(chunk + 1..2 * chunk) as u64;
        match conn.open(8, d, 4, 0.0, 1.0) {
            IngestEvent::Accepted => {}
            other => return Err(format!("open: {other:?}")),
        }
        let mut wrong = g.usize_in(1..2 * chunk);
        if wrong == chunk {
            wrong += 1;
        }
        match conn.chunk(8, 0, &vec![0.5f32; wrong]) {
            IngestEvent::Reject(8, IngestError::WrongChunkLen) => {}
            other => return Err(format!("misaligned split ({wrong}): {other:?}")),
        }
        Ok(())
    });
}

/// The five ingest wire messages survive the real codec: random payloads
/// through `to_frame` → `from_body` are identity.
#[test]
fn fuzz_ingest_wire_frames_roundtrip() {
    use quiver::coordinator::protocol::Msg;
    forall(fuzz_iters(80), 0xF4, |g: &mut Gen, _| {
        let msgs = [
            Msg::IngestOpen {
                task_id: g.u64(),
                d: g.u64() >> 12,
                s: g.usize_in(1..300) as u32,
                class: g.usize_in(0..256) as u8,
                deadline_ms: g.usize_in(0..60_000) as u32,
                lo: g.f64_in(-5.0..0.0),
                hi: g.f64_in(0.0..5.0),
            },
            Msg::IngestChunk {
                task_id: g.u64(),
                chunk_idx: g.usize_in(0..1 << 20) as u64,
                data: (0..g.usize_in(0..300)).map(|i| i as f32 * 0.5).collect(),
            },
            Msg::IngestClose { task_id: g.u64() },
            Msg::IngestSolved {
                task_id: g.u64(),
                levels: g.vec_f64(1..50, -4.0..4.0),
                solver: "quiver-ingest(M=64)".into(),
                solve_us: g.u64() >> 20,
            },
            Msg::IngestPayloadChunk {
                task_id: g.u64(),
                chunk_idx: g.usize_in(0..1 << 20) as u64,
                d: g.u64() >> 40,
                payload: (0..g.usize_in(0..200)).map(|i| i as u8).collect(),
            },
        ];
        for msg in msgs {
            let frame = msg.to_frame();
            let back = Msg::from_body(&frame[4..]).map_err(|e| e.to_string())?;
            if back != msg {
                return Err(format!("wire roundtrip changed {}", msg.kind()));
            }
        }
        Ok(())
    });
}

/// Bit-flip corruption of valid frames: decode either fails or yields a
/// structurally valid message — never panics, never over-allocates.
#[test]
fn prop_decoders_survive_bitflips() {
    use quiver::coordinator::protocol::Msg;
    forall(200, 0xAB, |g: &mut Gen, _| {
        let msg = Msg::CompressRequest {
            request_id: g.u64(),
            s: g.usize_in(1..64) as u32,
            class: g.usize_in(0..256) as u8,
            deadline_ms: g.usize_in(0..10_000) as u32,
            data: (0..g.usize_in(0..64)).map(|i| i as f32).collect(),
        };
        let mut frame = msg.to_frame();
        let body_len = frame.len() - 4;
        if body_len > 0 {
            let pos = 4 + g.usize_in(0..body_len);
            let bit = g.usize_in(0..8);
            frame[pos] ^= 1 << bit;
        }
        let _ = Msg::from_body(&frame[4..]); // must not panic either way
        Ok(())
    });
}
