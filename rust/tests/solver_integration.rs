//! Cross-module integration: the full compression pipeline
//! (solve → quantize → encode → decode → measure) at realistic sizes, and
//! larger-scale solver cross-agreement than the unit tests cover.

use quiver::avq::histogram::{solve_hist, HistConfig};
use quiver::avq::{self, Prefix, SolverKind};
use quiver::dist::Dist;
use quiver::metrics::{sum_variances, vnmse};
use quiver::sq;
use quiver::util::rng::Xoshiro256pp;

/// Empirical MSE of repeated stochastic quantization converges to the
/// analytic sum of variances the solver optimizes — the whole point of the
/// objective.
#[test]
fn empirical_mse_matches_analytic_objective() {
    let d = 4096;
    let xs = Dist::LogNormal { mu: 0.0, sigma: 1.0 }.sample_sorted(d, 11);
    let p = Prefix::unweighted(&xs);
    let sol = avq::solve(&p, 8, SolverKind::QuiverAccel).unwrap();
    let analytic = sol.mse;
    let mut rng = Xoshiro256pp::seed_from_u64(123);
    let trials = 300;
    let mut acc = 0.0;
    for _ in 0..trials {
        let idx = sq::quantize_sorted(&xs, &sol.q, &mut rng);
        let err: f64 = xs
            .iter()
            .zip(&idx)
            .map(|(&x, &i)| {
                let e = sol.q[i as usize] - x;
                e * e
            })
            .sum();
        acc += err;
    }
    let empirical = acc / trials as f64;
    let rel = (empirical - analytic).abs() / analytic;
    assert!(
        rel < 0.05,
        "empirical {empirical} vs analytic {analytic} (rel {rel})"
    );
}

/// All four production solvers agree at d = 20_000 on every paper
/// distribution (exhaustive can't go here; they check each other).
#[test]
fn solvers_agree_at_scale() {
    for (seed, (name, dist)) in Dist::paper_suite().into_iter().enumerate() {
        let xs = dist.sample_sorted(20_000, 50 + seed as u64);
        let p = Prefix::unweighted(&xs);
        for s in [4, 16] {
            let quiver = avq::solve(&p, s, SolverKind::Quiver).unwrap();
            let bins = avq::solve(&p, s, SolverKind::BinSearch).unwrap();
            let accel = avq::solve(&p, s, SolverKind::QuiverAccel).unwrap();
            assert!(
                (quiver.mse - bins.mse).abs() < 1e-9 * quiver.mse.max(1e-12),
                "{name} s={s}: quiver={} binsearch={}",
                quiver.mse,
                bins.mse
            );
            assert!(
                (quiver.mse - accel.mse).abs() < 1e-9 * quiver.mse.max(1e-12),
                "{name} s={s}: quiver={} accel={}",
                quiver.mse,
                accel.mse
            );
        }
    }
}

/// Figure-2 behaviour: vNMSE of the histogram solution approaches the
/// optimum as M grows, and M = √d·log d is already within a few percent.
#[test]
fn hist_vnmse_converges_to_optimal_in_m() {
    let d = 1 << 14;
    let xs_raw = Dist::LogNormal { mu: 0.0, sigma: 1.0 }.sample_vec(d, 77);
    let mut xs = xs_raw.clone();
    xs.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    let p = Prefix::unweighted(&xs);
    let s = 8;
    let opt = avq::solve(&p, s, SolverKind::QuiverAccel).unwrap();
    let v_opt = opt.mse / p.norm2_sq();
    let mut last = f64::INFINITY;
    for m in [16usize, 64, 256, 1024] {
        let sol = solve_hist(&xs_raw, s, &HistConfig::fixed(m)).unwrap();
        let v = vnmse(&xs, &sol.q);
        assert!(v + 1e-12 >= v_opt, "approx can't beat optimal");
        // Not strictly monotone (stochastic rounding), but the trend must
        // hold across 4x steps.
        assert!(v < last * 1.5, "vNMSE blew up at M={m}: {v} vs {last}");
        last = v;
    }
    assert!(
        last <= v_opt * 1.05,
        "M=1024 should be within 5%: {last} vs optimal {v_opt}"
    );
}

/// End-to-end compression pipeline at 1M coordinates through the
/// histogram path (the paper's "on the fly" regime): solve, quantize,
/// pack, unpack, and verify both the error and the wire size.
#[test]
fn million_coordinate_pipeline() {
    let d = 1 << 20;
    let xs = Dist::Normal { mu: 0.0, sigma: 1.0 }.sample_vec(d, 99);
    let s = 16;
    let t0 = std::time::Instant::now();
    let sol = solve_hist(&xs, s, &HistConfig::fixed(400)).unwrap();
    let solve_time = t0.elapsed();
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    let c = sq::compress(&xs, &sol.q, &mut rng);
    assert_eq!(c.d as usize, d);
    assert_eq!(c.bits, 4);
    // 4 bits/coord + header.
    assert!(c.wire_size() < d / 2 + 1024);
    let back = sq::decompress(&c);
    assert_eq!(back.len(), d);
    // vNMSE sanity for s=16 on a normal vector. (Unbiased SQ pays for the
    // ±5σ range at d=1M; the optimum here is ~2-3%, far below 1-bit's ~30%.)
    let mut sorted = xs.clone();
    sorted.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    let v = vnmse(&sorted, &sol.q);
    assert!(v < 0.05, "vNMSE {v}");
    // Generous wall-clock budget (debug builds are slow; release is ~ms).
    assert!(
        solve_time.as_secs_f64() < 30.0,
        "hist solve took {solve_time:?}"
    );
}

/// Baselines never beat the optimum and respect their documented
/// guarantees at realistic scale.
#[test]
fn baselines_bounded_by_optimum_at_scale() {
    use quiver::baselines::Method;
    let xs = Dist::Weibull { shape: 1.0, scale: 1.0 }.sample_sorted(1 << 14, 13);
    let p = Prefix::unweighted(&xs);
    let s = 8;
    let opt = avq::solve(&p, s, SolverKind::QuiverAccel).unwrap();
    for m in [
        Method::QuiverHist { m: 400 },
        Method::ZipMlCpUniform { m: 400 },
        Method::ZipMlCpQuantile { m: 400 },
        Method::Alq { iters: 10 },
        Method::UniformSq,
    ] {
        let q = m.quantization_values(&xs, s);
        let err = sum_variances(&xs, &q);
        assert!(
            err + 1e-9 >= opt.mse,
            "{} beat the optimum: {err} < {}",
            m.name(),
            opt.mse
        );
    }
    // 2-Apx uses 2s values; bounded by twice the s-optimal.
    let q2 = Method::ZipMl2Apx.quantization_values(&xs, s);
    let err2 = sum_variances(&xs, &q2);
    assert!(err2 <= 2.0 * opt.mse + 1e-9, "2apx {err2} vs 2*opt {}", 2.0 * opt.mse);
}
