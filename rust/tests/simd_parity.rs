//! Differential SIMD parity suite: every vectorized chunk kernel must be
//! **bit-identical** to its scalar twin — same floats, same indices, same
//! payload bytes — over the `dist::paper_suite()` families *and* over
//! adversarial inputs the distributions never produce (NaN and ±∞ in
//! every lane position, denormals, signed zeros, ragged chunk tails of
//! every residue mod the lane width, and the `d = 0 / 1` degenerate
//! shapes).
//!
//! Strategy: run the same computation under forced-scalar and — when the
//! CPU has it — forced-AVX2 kernels (`par::simd::set_simd`), and compare
//! via `f64::to_bits` / raw bytes, never `PartialEq` on floats (which
//! would hide `-0.0` vs `0.0` and NaN payload differences). On a machine
//! without AVX2 the suite still runs scalar-vs-scalar, so it never
//! vacuously passes in CI's forced-scalar leg; the dedicated AVX2 leg
//! compiles with `-Ctarget-feature=+avx2` and re-runs everything here.
//!
//! The SIMD selection is process-global, so tests that pin it serialize
//! on `MODE_LOCK` (libtest runs one binary's tests concurrently).

use quiver::avq::histogram::{solve_hist, GridHistogram, HistConfig};
use quiver::dist::Dist;
use quiver::par::{self, simd};
use quiver::sq;
use quiver::util::rng::Xoshiro256pp;
use std::sync::Mutex;

/// Serializes tests that pin the process-global SIMD mode.
static MODE_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` once per SIMD mode available on this machine (scalar always,
/// AVX2 when detected) and return the labelled results. Restores the
/// prior selection even on panic via a drop guard.
fn under_modes<T>(f: impl Fn() -> T) -> Vec<(simd::SimdMode, T)> {
    struct Restore(simd::SimdMode);
    impl Drop for Restore {
        fn drop(&mut self) {
            simd::set_simd(self.0);
        }
    }
    let _g = MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = Restore(simd::simd());
    let mut modes = vec![simd::SimdMode::Scalar];
    if simd::detected_avx2() {
        modes.push(simd::SimdMode::Avx2);
    }
    modes
        .into_iter()
        .map(|m| {
            simd::set_simd(m);
            (m, f())
        })
        .collect()
}

/// Assert every mode produced the same `T` (which must already be a
/// bit-exact representation — `to_bits`/bytes, not floats).
fn assert_modes_agree<T: PartialEq + std::fmt::Debug>(results: Vec<(simd::SimdMode, T)>, ctx: &str) {
    let (m0, r0) = &results[0];
    for (m, r) in &results[1..] {
        assert_eq!(r, r0, "{ctx}: {} diverged from {}", m.name(), m0.name());
    }
}

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Lengths that exercise every ragged-tail residue mod the lane width,
/// the empty and single-element shapes, and a couple of chunk-boundary
/// straddlers.
fn tail_lengths() -> Vec<usize> {
    let mut v: Vec<usize> = (0..=2 * simd::LANES + 1).collect();
    v.extend([100, 1000, par::CHUNK - 1, par::CHUNK, par::CHUNK + 13]);
    v
}

/// Adversarial values the paper distributions never emit.
const SPECIALS: &[f64] = &[
    f64::NAN,
    f64::INFINITY,
    f64::NEG_INFINITY,
    f64::MIN_POSITIVE,        // smallest normal
    f64::MIN_POSITIVE / 2.0,  // denormal
    -f64::MIN_POSITIVE / 2.0, // negative denormal
    0.0,
    -0.0,
];

#[test]
fn scan_stats_parity_paper_suite_and_tails() {
    for (name, dist) in Dist::paper_suite() {
        for len in tail_lengths() {
            let xs = dist.sample_vec(len, 0x51AD ^ len as u64);
            let got = under_modes(|| {
                let st = par::scan::stats(&xs);
                (st.lo.to_bits(), st.hi.to_bits(), st.norm2_sq.to_bits(), st.finite)
            });
            assert_modes_agree(got, &format!("stats({name}, len={len})"));
        }
    }
}

#[test]
fn scan_chunk_parity_adversarial_placements() {
    // Every special value in every lane position of the head group, the
    // pairwise-merge seams, and the ragged tail.
    for len in [1usize, 3, 4, 5, 7, 8, 9, 12, 31] {
        let base = Dist::Normal { mu: 0.0, sigma: 1.0 }.sample_vec(len, 77);
        for &special in SPECIALS {
            for pos in 0..len {
                let mut xs = base.clone();
                xs[pos] = special;
                let got = under_modes(|| {
                    let (lo, hi, n2, fin) = simd::scan_chunk(&xs);
                    (lo.to_bits(), hi.to_bits(), n2.to_bits(), fin)
                });
                assert_modes_agree(
                    got,
                    &format!("scan_chunk(len={len}, xs[{pos}]={special:?})"),
                );
            }
        }
    }
    // Empty-input identities hold in every mode.
    let got = under_modes(|| {
        let (lo, hi, n2, fin) = simd::scan_chunk(&[]);
        (lo.to_bits(), hi.to_bits(), n2.to_bits(), fin)
    });
    for (m, (lo, hi, n2, fin)) in got {
        assert_eq!(lo, f64::INFINITY.to_bits(), "{}", m.name());
        assert_eq!(hi, f64::NEG_INFINITY.to_bits(), "{}", m.name());
        assert_eq!(n2, 0.0f64.to_bits(), "{}", m.name());
        assert!(fin, "{}", m.name());
    }
}

#[test]
fn grid_positions_parity_including_denormals() {
    for len in tail_lengths() {
        let mut xs = Dist::LogNormal { mu: 0.0, sigma: 1.0 }.sample_vec(len, 0xAB ^ len as u64);
        // Denormals and signed zeros are legal grid inputs (finite).
        for (i, &s) in SPECIALS[3..].iter().enumerate() {
            if !xs.is_empty() {
                let k = (i * 5 + 1) % xs.len();
                xs[k] = s;
            }
        }
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min).min(0.0) - 1.0;
        let inv_delta = 0.37;
        let got = under_modes(|| {
            let mut t = vec![0.0f64; xs.len()];
            let mut f = vec![0.0f64; xs.len()];
            simd::grid_positions(&xs, lo, inv_delta, &mut t, &mut f);
            (bits(&t), bits(&f))
        });
        assert_modes_agree(got, &format!("grid_positions(len={len})"));
    }
}

#[test]
fn fill_brackets_parity_exact_hits_and_edges() {
    // Levels with exact duplicates of input values, so the `<=` tie rule
    // is exercised, plus inputs pinned to the first/last level.
    let qs: Vec<f64> = vec![-3.0, -1.5, -1.5 + 1e-12, 0.0, 0.25, 2.0, 7.5];
    for len in tail_lengths() {
        let mut g = Xoshiro256pp::seed_from_u64(len as u64 + 9);
        let xs: Vec<f64> = (0..len)
            .map(|i| match i % 5 {
                0 => qs[i % qs.len()],                       // exact level hit
                1 => *qs.first().unwrap(),                   // left edge
                2 => *qs.last().unwrap(),                    // right edge
                _ => -3.0 + 10.5 * g.next_f64(),             // interior
            })
            .collect();
        let got = under_modes(|| {
            let mut sel = vec![0u32; xs.len()];
            let mut hi = vec![0u32; xs.len()];
            simd::fill_brackets(&qs, &xs, &mut sel, &mut hi);
            (sel, hi)
        });
        assert_modes_agree(got, &format!("fill_brackets(len={len})"));
    }
}

#[test]
fn gather_levels_parity_first_last_and_ragged() {
    // Level tables around the i32-gather group size, indices slamming the
    // first and last entries (the bounds the AVX2 guard watches).
    for n_levels in [1usize, 2, 3, 4, 5, 300] {
        let qs: Vec<f64> = (0..n_levels).map(|i| i as f64 * 0.5 - 3.0).collect();
        for len in tail_lengths() {
            let mut g = Xoshiro256pp::seed_from_u64((n_levels * 1000 + len) as u64);
            let idx: Vec<u32> = (0..len)
                .map(|i| match i % 4 {
                    0 => 0,
                    1 => (n_levels - 1) as u32,
                    _ => g.next_below(n_levels as u64) as u32,
                })
                .collect();
            let got = under_modes(|| {
                let mut out = vec![0.0f64; idx.len()];
                simd::gather_levels(&qs, &idx, &mut out);
                bits(&out)
            });
            assert_modes_agree(got, &format!("gather_levels(levels={n_levels}, len={len})"));
        }
    }
}

#[test]
fn histogram_counts_bitwise_equal_across_modes() {
    for (name, dist) in Dist::paper_suite() {
        for (d, m) in [(1usize, 2usize), (100, 64), (par::CHUNK + 777, 777), (2 * par::CHUNK + 3, 129)]
        {
            let xs = dist.sample_vec(d, 0xBADD ^ d as u64);
            let got = under_modes(|| {
                let mut rng = Xoshiro256pp::seed_from_u64(0xD17E);
                let h = GridHistogram::build(&xs, m, &mut rng).unwrap();
                (bits(&h.weights), bits(&h.grid), h.norm2_sq.to_bits(), h.lo.to_bits(), h.hi.to_bits())
            });
            assert_modes_agree(got, &format!("histogram({name}, d={d}, m={m})"));
        }
    }
}

#[test]
fn quantize_dequantize_and_payload_parity() {
    // s = 16 exercises the sub-byte general codec path, s = 256 the
    // byte-aligned u8 fast path; both must be invisible in the bits.
    for (name, dist) in Dist::paper_suite() {
        for s in [3usize, 16, 256] {
            for d in [1usize, 2, 7, 8, 9, 1000, par::CHUNK + 13] {
                let xs = dist.sample_vec(d, 0xE44 ^ (d * s) as u64);
                // Level set spanning the input range (quantize requires
                // qs[0] ≤ x ≤ qs[last]), built without the solver to keep
                // the matrix cheap.
                let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
                let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let qs: Vec<f64> = (0..s)
                    .map(|i| lo + (hi - lo) * i as f64 / (s - 1) as f64)
                    .collect();
                let got = under_modes(|| {
                    let mut rng = Xoshiro256pp::seed_from_u64(0xBEEF);
                    let idx = sq::quantize(&xs, &qs, &mut rng);
                    let c = sq::encode(&idx, &qs);
                    let (back, back_qs) = sq::decode(&c);
                    assert_eq!(back, idx, "decode(encode(idx)) != idx");
                    let vals = sq::dequantize(&back, &back_qs);
                    (idx, c.payload, bits(&vals))
                });
                assert_modes_agree(got, &format!("quantize({name}, s={s}, d={d})"));
            }
        }
    }
}

#[test]
fn solve_hist_levels_parity() {
    // The full histogram → solver → levels path: the level *values* and
    // positions must not depend on the instruction set.
    for (name, dist) in Dist::paper_suite() {
        let xs = dist.sample_vec(par::CHUNK + 321, 0xF00D);
        let got = under_modes(|| {
            let sol = solve_hist(&xs, 16, &HistConfig::fixed(777)).unwrap();
            (bits(&sol.q), sol.q_idx.clone(), sol.mse.to_bits())
        });
        assert_modes_agree(got, &format!("solve_hist({name})"));
    }
}

#[test]
fn pack_unpack_parity_every_aligned_width() {
    // bits = 8 and 16 are reachable through encode; bits = 32 would need
    // more than 2³¹ levels, so the payload kernels are driven directly.
    for bits in [8u8, 16, 32] {
        let bpe = usize::from(bits) / 8;
        for len in tail_lengths() {
            if len > 4096 {
                continue; // direct-call coverage doesn't need chunk-scale inputs
            }
            let mut g = Xoshiro256pp::seed_from_u64(len as u64 * 31 + u64::from(bits));
            let max = if bits == 32 { u64::from(u32::MAX) + 1 } else { 1u64 << bits };
            let chunk: Vec<u32> = (0..len)
                .map(|i| match i % 3 {
                    0 => 0,
                    1 => (max - 1) as u32,
                    _ => g.next_below(max) as u32,
                })
                .collect();
            let packed = under_modes(|| {
                let mut window = vec![0u8; chunk.len() * bpe];
                simd::pack_bytes(&chunk, &mut window, bits);
                window
            });
            let window = packed[0].1.clone();
            assert_modes_agree(packed, &format!("pack_bytes(bits={bits}, len={len})"));
            let unpacked = under_modes(|| {
                let mut out = vec![0u32; len];
                simd::unpack_bytes(&window, &mut out, bits);
                out
            });
            assert_eq!(unpacked[0].1, chunk, "roundtrip(bits={bits}, len={len})");
            assert_modes_agree(unpacked, &format!("unpack_bytes(bits={bits}, len={len})"));
        }
    }
}

#[test]
fn wide_codec_roundtrip_u16_levels() {
    // 65536 levels → 16-bit byte-aligned codec over a multi-chunk index
    // stream with a ragged tail.
    let s = 1usize << 16;
    let qs: Vec<f64> = (0..s).map(|i| i as f64).collect();
    let d = par::CHUNK + 4321;
    let mut g = Xoshiro256pp::seed_from_u64(0x16B);
    let idx: Vec<u32> = (0..d).map(|_| g.next_below(s as u64) as u32).collect();
    let got = under_modes(|| {
        let c = sq::encode(&idx, &qs);
        let (back, _) = sq::decode(&c);
        assert_eq!(back, idx, "u16 roundtrip");
        c.payload
    });
    assert_modes_agree(got, "encode(s=65536)");
}
