//! The determinism contract of `quiver::par`, tested end to end: every
//! parallel hot pass — histogram build, `solve_hist`, quantize, bit-pack
//! encode, and the parallel sort — must be **bitwise-identical** across
//! thread counts 1/2/4/8, on every `dist::paper_suite()` family.
//!
//! The tests mutate the process-global executor width, and libtest runs
//! tests of one binary concurrently — `WIDTH_LOCK` serializes them so a
//! pinned width stays pinned while a snapshot is measured.

use quiver::avq::histogram::{solve_hist, GridHistogram, HistConfig};
use quiver::avq::{self, SolverKind};
use quiver::dist::Dist;
use quiver::par;
use quiver::sq;
use quiver::util::rng::Xoshiro256pp;

/// Crosses several chunk boundaries and ends in a ragged tail.
const D: usize = 3 * par::CHUNK + 1234;

/// Serializes tests that pin the global executor width.
static WIDTH_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Everything a hot pass produces, in bit-exact form (`f64::to_bits` —
/// `PartialEq` on f64 would hide `-0.0` vs `0.0` differences).
#[derive(PartialEq, Debug)]
struct Snapshot {
    hist_weights: Vec<u64>,
    hist_grid: Vec<u64>,
    hist_norm2: u64,
    sol_q: Vec<u64>,
    sol_idx: Vec<usize>,
    sol_mse: u64,
    quant_idx: Vec<u32>,
    quant_sorted_idx: Vec<u32>,
    payload: Vec<u8>,
    sorted: Vec<u64>,
}

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

fn snapshot(xs: &[f64]) -> Snapshot {
    let mut rng = Xoshiro256pp::seed_from_u64(0xD17E);
    let h = GridHistogram::build(xs, 777, &mut rng).unwrap();
    let sol = solve_hist(xs, 16, &HistConfig::fixed(777)).unwrap();
    let mut q_rng = Xoshiro256pp::seed_from_u64(0xBEEF);
    let quant_idx = sq::quantize(xs, &sol.q, &mut q_rng);
    let payload = sq::encode(&quant_idx, &sol.q).payload;
    let mut sorted = xs.to_vec();
    par::sort::sort_f64(&mut sorted);
    // The documented contract: on the same input and RNG state, the merge
    // scan and the binary-search path agree draw-for-draw — asserted here
    // on a multi-chunk input (the sq unit test only covers one chunk).
    let mut qs_rng = Xoshiro256pp::seed_from_u64(0xBEEF);
    let quant_sorted_idx = sq::quantize_sorted(&sorted, &sol.q, &mut qs_rng);
    let mut agree_rng = Xoshiro256pp::seed_from_u64(0xBEEF);
    assert_eq!(
        sq::quantize(&sorted, &sol.q, &mut agree_rng),
        quant_sorted_idx,
        "quantize vs quantize_sorted diverged on identical input + RNG state"
    );
    Snapshot {
        hist_weights: bits(&h.weights),
        hist_grid: bits(&h.grid),
        hist_norm2: h.norm2_sq.to_bits(),
        sol_q: bits(&sol.q),
        sol_idx: sol.q_idx.clone(),
        sol_mse: sol.mse.to_bits(),
        quant_idx,
        quant_sorted_idx,
        payload,
        sorted: bits(&sorted),
    }
}

#[test]
fn hot_passes_bitwise_identical_across_thread_counts() {
    let _guard = WIDTH_LOCK.lock().unwrap();
    let prev = par::threads();
    for (name, dist) in Dist::paper_suite() {
        let xs = dist.sample_vec(D, 0xC0FFEE);
        par::set_threads(1);
        let reference = snapshot(&xs);
        // Single-thread sanity: the sort really sorted, mass conserved.
        assert!(reference.sorted.windows(2).all(|w| f64::from_bits(w[0]) <= f64::from_bits(w[1])));
        for t in [2usize, 4, 8] {
            par::set_threads(t);
            let got = snapshot(&xs);
            assert_eq!(reference, got, "{name}: outputs diverged at {t} threads");
        }
    }
    par::set_threads(prev);
}

/// The exact-solver entry point (scan + parallel sort + solve) is also
/// invariant — and matches a hand-rolled sequential sort + solve.
#[test]
fn solve_unsorted_invariant_and_correct() {
    let _guard = WIDTH_LOCK.lock().unwrap();
    let prev = par::threads();
    let xs = Dist::LogNormal { mu: 0.0, sigma: 1.0 }.sample_vec(D, 0xFACE);
    let mut sorted = xs.clone();
    sorted.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    let p = avq::Prefix::unweighted(&sorted);
    let want = avq::solve(&p, 16, SolverKind::QuiverAccel).unwrap();
    for t in [1usize, 2, 4, 8] {
        par::set_threads(t);
        let got = avq::solve_unsorted(&xs, 16, SolverKind::QuiverAccel).unwrap();
        assert_eq!(got.q_idx, want.q_idx, "t={t}");
        assert_eq!(bits(&got.q), bits(&want.q), "t={t}");
        assert_eq!(got.mse.to_bits(), want.mse.to_bits(), "t={t}");
    }
    par::set_threads(prev);
}

/// Decode is the inverse of encode under any width, and dequantize
/// round-trips through the parallel paths.
#[test]
fn codec_roundtrip_under_parallel_widths() {
    let _guard = WIDTH_LOCK.lock().unwrap();
    let prev = par::threads();
    let xs = Dist::Exponential { lambda: 1.0 }.sample_vec(D, 0xABCD);
    let sol = solve_hist(&xs, 16, &HistConfig::fixed(300)).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(3);
    let idx = sq::quantize(&xs, &sol.q, &mut rng);
    for t in [1usize, 3, 8] {
        par::set_threads(t);
        let c = sq::encode(&idx, &sol.q);
        let (back, qs) = sq::decode(&c);
        assert_eq!(back, idx, "t={t}");
        let vals = sq::dequantize(&back, &qs);
        assert!(vals.iter().all(|v| sol.q.contains(v)), "t={t}");
    }
    par::set_threads(prev);
}
