//! The determinism contract of `quiver::par`, tested end to end: every
//! parallel hot pass — histogram build, `solve_hist`, quantize, bit-pack
//! encode, and the parallel sort — must be **bitwise-identical** across
//! thread counts 1/2/4/8, **across execution backends** (persistent
//! worker pool vs per-call scoped spawning), **and across SIMD modes**
//! (scalar vs AVX2 chunk kernels, when the CPU has AVX2), on every
//! `dist::paper_suite()` family. The matrix tests walk the full
//! `threads × backend × simd` cross product through
//! `testutil::for_each_exec_cell`, so a red cell names its exact
//! configuration. Plus the pool lifecycle: shutdown, lazy reinit, and
//! mid-run resize must neither lose work nor change results; and the
//! multi-tenant batched dispatch must equal the one-vector-at-a-time
//! path per tenant.
//!
//! The tests mutate the process-global executor width/backend/SIMD
//! selection, and libtest runs tests of one binary concurrently —
//! `WIDTH_LOCK` serializes them so a pinned width stays pinned while a
//! snapshot is measured. (Every test in this file takes the lock, so
//! pool worker counts are stable to assert on here — unlike in the lib
//! unit tests. `for_each_exec_cell` takes its own inner lock and no
//! other, so holding `WIDTH_LOCK` around it is deadlock-free.)

use quiver::avq::histogram::{solve_hist, GridHistogram, HistConfig};
use quiver::avq::{self, SolverKind};
use quiver::dist::Dist;
use quiver::par;
use quiver::sq;
use quiver::testutil::for_each_exec_cell;
use quiver::util::rng::Xoshiro256pp;

/// Crosses several chunk boundaries and ends in a ragged tail.
const D: usize = 3 * par::CHUNK + 1234;

/// Serializes tests that pin the global executor width.
static WIDTH_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Everything a hot pass produces, in bit-exact form (`f64::to_bits` —
/// `PartialEq` on f64 would hide `-0.0` vs `0.0` differences).
#[derive(PartialEq, Debug)]
struct Snapshot {
    hist_weights: Vec<u64>,
    hist_grid: Vec<u64>,
    hist_norm2: u64,
    sol_q: Vec<u64>,
    sol_idx: Vec<usize>,
    sol_mse: u64,
    quant_idx: Vec<u32>,
    quant_sorted_idx: Vec<u32>,
    payload: Vec<u8>,
    sorted: Vec<u64>,
}

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

fn snapshot(xs: &[f64]) -> Snapshot {
    let mut rng = Xoshiro256pp::seed_from_u64(0xD17E);
    let h = GridHistogram::build(xs, 777, &mut rng).unwrap();
    let sol = solve_hist(xs, 16, &HistConfig::fixed(777)).unwrap();
    let mut q_rng = Xoshiro256pp::seed_from_u64(0xBEEF);
    let quant_idx = sq::quantize(xs, &sol.q, &mut q_rng);
    let payload = sq::encode(&quant_idx, &sol.q).payload;
    let mut sorted = xs.to_vec();
    par::sort::sort_f64(&mut sorted);
    // The documented contract: on the same input and RNG state, the merge
    // scan and the binary-search path agree draw-for-draw — asserted here
    // on a multi-chunk input (the sq unit test only covers one chunk).
    let mut qs_rng = Xoshiro256pp::seed_from_u64(0xBEEF);
    let quant_sorted_idx = sq::quantize_sorted(&sorted, &sol.q, &mut qs_rng);
    let mut agree_rng = Xoshiro256pp::seed_from_u64(0xBEEF);
    assert_eq!(
        sq::quantize(&sorted, &sol.q, &mut agree_rng),
        quant_sorted_idx,
        "quantize vs quantize_sorted diverged on identical input + RNG state"
    );
    Snapshot {
        hist_weights: bits(&h.weights),
        hist_grid: bits(&h.grid),
        hist_norm2: h.norm2_sq.to_bits(),
        sol_q: bits(&sol.q),
        sol_idx: sol.q_idx.clone(),
        sol_mse: sol.mse.to_bits(),
        quant_idx,
        quant_sorted_idx,
        payload,
        sorted: bits(&sorted),
    }
}

/// Restores width, backend, and SIMD mode even if an assertion panics, so
/// a failure cannot leak a pinned configuration into later tests.
struct ParGuard {
    width: usize,
    backend: par::Backend,
    simd: par::simd::SimdMode,
}

impl ParGuard {
    fn pin() -> Self {
        Self { width: par::threads(), backend: par::backend(), simd: par::simd::simd() }
    }
}

impl Drop for ParGuard {
    fn drop(&mut self) {
        par::set_threads(self.width);
        par::set_backend(self.backend);
        par::simd::set_simd(self.simd);
    }
}

#[test]
fn hot_passes_bitwise_identical_across_thread_counts_and_backends() {
    let _guard = WIDTH_LOCK.lock().unwrap();
    let _restore = ParGuard::pin();
    for (name, dist) in Dist::paper_suite() {
        let xs = dist.sample_vec(D, 0xC0FFEE);
        // The reference is the most boring configuration there is: one
        // thread, scoped spawning, forced-scalar kernels. Every matrix
        // cell below must reproduce it bit for bit.
        par::set_backend(par::Backend::Scoped);
        par::set_threads(1);
        par::simd::set_simd(par::simd::SimdMode::Scalar);
        let reference = snapshot(&xs);
        // Single-thread sanity: the sort really sorted, mass conserved.
        assert!(reference.sorted.windows(2).all(|w| f64::from_bits(w[0]) <= f64::from_bits(w[1])));
        for_each_exec_cell(&[1, 2, 4, 8], |cell| {
            let got = snapshot(&xs);
            assert_eq!(reference, got, "{name}: outputs diverged at cell [{cell}]");
        });
    }
}

/// Pool lifecycle under real workloads: shutdown retires every worker,
/// the next pass lazily re-initializes, and a mid-run resize (the
/// `QUIVER_THREADS`-driven path) converges to the new width — all without
/// changing a single output bit.
#[test]
fn pool_shutdown_reinit_and_resize_mid_run() {
    let _guard = WIDTH_LOCK.lock().unwrap();
    let _restore = ParGuard::pin();
    par::set_backend(par::Backend::Pool);
    let xs = Dist::LogNormal { mu: 0.0, sigma: 1.0 }.sample_vec(D, 0x9001);
    par::set_threads(1);
    let reference = snapshot(&xs);

    // Warm the pool at width 4 and check the worker census.
    par::set_threads(4);
    assert_eq!(snapshot(&xs), reference, "width 4 (pool warm-up)");
    assert_eq!(par::pool::worker_count(), 3, "width 4 keeps 3 workers");

    // Graceful shutdown: every worker retires...
    par::pool::shutdown();
    assert_eq!(par::pool::worker_count(), 0, "shutdown retires every worker");
    // ...and the very next pass transparently re-initializes the pool.
    assert_eq!(snapshot(&xs), reference, "after shutdown + lazy reinit");
    assert_eq!(par::pool::worker_count(), 3, "pool re-initialized to width 4");

    // Resize mid-run: grow to 8, then shrink to 2. Excess workers retire
    // at their next wakeup, so poll briefly after the shrink.
    par::set_threads(8);
    assert_eq!(snapshot(&xs), reference, "width 8 (grown)");
    assert_eq!(par::pool::worker_count(), 7, "width 8 keeps 7 workers");
    par::set_threads(2);
    assert_eq!(snapshot(&xs), reference, "width 2 (shrunk)");
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while par::pool::worker_count() > 1 && std::time::Instant::now() < deadline {
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    assert_eq!(par::pool::worker_count(), 1, "width 2 keeps 1 worker");
    par::pool::shutdown();
}

/// Multi-tenant batched dispatch: compressing a batch of small tenant
/// vectors in one pool wave yields, per tenant, exactly the bytes the
/// one-vector-at-a-time path produces with the same derived stream — at
/// every width and on both backends.
#[test]
fn batched_dispatch_equals_one_at_a_time() {
    let _guard = WIDTH_LOCK.lock().unwrap();
    let _restore = ParGuard::pin();
    // 40 small tenants, mixed sizes and families (all ≪ one chunk — the
    // serving case batching exists for).
    let suite = Dist::paper_suite();
    let tenants_data: Vec<Vec<f64>> = (0..40u64)
        .map(|t| {
            let (_, dist) = suite[(t as usize) % suite.len()];
            dist.sample_vec(200 + 97 * (t as usize % 7), 0x7E7E + t)
        })
        .collect();
    let qsets: Vec<Vec<f64>> = tenants_data
        .iter()
        .map(|xs| solve_hist(xs, 8, &HistConfig::fixed(128)).unwrap().q)
        .collect();
    let tenants: Vec<(&[f64], &[f64])> = tenants_data
        .iter()
        .zip(&qsets)
        .map(|(xs, qs)| (xs.as_slice(), qs.as_slice()))
        .collect();
    // One-at-a-time reference with the documented per-tenant streams.
    let mut ref_rng = Xoshiro256pp::seed_from_u64(0x5EED);
    let base = ref_rng.next_u64();
    let reference: Vec<sq::CompressedVec> = tenants
        .iter()
        .enumerate()
        .map(|(j, (xs, qs))| sq::compress(xs, qs, &mut Xoshiro256pp::stream(base, j as u64)))
        .collect();
    for_each_exec_cell(&[1, 2, 4, 8], |cell| {
        let mut rng = Xoshiro256pp::seed_from_u64(0x5EED);
        let got = sq::compress_batch(tenants.clone(), &mut rng);
        assert_eq!(got.len(), reference.len());
        for (j, (g, r)) in got.iter().zip(&reference).enumerate() {
            assert_eq!(g, r, "tenant {j} diverged at cell [{cell}]");
        }
    });
}

/// One batch of small tenants costs exactly one pool wave (the sealed
/// handoff the batching exists to buy), versus one-wave-per-pass when the
/// tenants are compressed individually.
#[test]
fn batched_dispatch_is_one_wave() {
    let _guard = WIDTH_LOCK.lock().unwrap();
    let _restore = ParGuard::pin();
    par::set_backend(par::Backend::Pool);
    par::set_threads(4);
    let tenants_data: Vec<Vec<f64>> =
        (0..64u64).map(|t| Dist::Uniform { lo: 0.0, hi: 1.0 }.sample_vec(512, t)).collect();
    let qs: Vec<f64> = (0..=8).map(|i| i as f64 / 8.0).collect();
    let tenants: Vec<(&[f64], &[f64])> =
        tenants_data.iter().map(|xs| (xs.as_slice(), qs.as_slice())).collect();
    let mut rng = Xoshiro256pp::seed_from_u64(1);
    let waves_before = par::pool::wave_count();
    let out = sq::compress_batch(tenants, &mut rng);
    let waves_after = par::pool::wave_count();
    assert_eq!(out.len(), 64);
    assert_eq!(
        waves_after - waves_before,
        1,
        "64 small tenants must cost exactly one sealed pool handoff"
    );
}

/// The exact-solver entry point (scan + parallel sort + solve) is also
/// invariant — and matches a hand-rolled sequential sort + solve.
#[test]
fn solve_unsorted_invariant_and_correct() {
    let _guard = WIDTH_LOCK.lock().unwrap();
    let prev = par::threads();
    let xs = Dist::LogNormal { mu: 0.0, sigma: 1.0 }.sample_vec(D, 0xFACE);
    let mut sorted = xs.clone();
    sorted.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    let p = avq::Prefix::unweighted(&sorted);
    let want = avq::solve(&p, 16, SolverKind::QuiverAccel).unwrap();
    for t in [1usize, 2, 4, 8] {
        par::set_threads(t);
        let got = avq::solve_unsorted(&xs, 16, SolverKind::QuiverAccel).unwrap();
        assert_eq!(got.q_idx, want.q_idx, "t={t}");
        assert_eq!(bits(&got.q), bits(&want.q), "t={t}");
        assert_eq!(got.mse.to_bits(), want.mse.to_bits(), "t={t}");
    }
    par::set_threads(prev);
}

/// The sort's per-thread scratch buffer (reused across calls since the
/// ROADMAP follow-up landed) must be invisible in results: back-to-back
/// sorts of different sizes — where a later, smaller sort sees the stale
/// tail of an earlier sort's scratch — stay bit-identical to the
/// sequential reference at every width and on both backends, and
/// repeated sorts of the same data are bit-identical to each other.
#[test]
fn sort_scratch_reuse_bit_identical_across_widths() {
    let _guard = WIDTH_LOCK.lock().unwrap();
    let _restore = ParGuard::pin();
    // Sizes chosen to flip the merge-round parity (data ending in the
    // scratch vs in place) and to shrink after growing.
    let sizes = [
        2 * par::sort::RUN + 5,
        4 * par::sort::RUN + 999,
        par::sort::RUN + 1,
        3 * par::sort::RUN + par::sort::RUN / 2,
    ];
    let inputs: Vec<Vec<f64>> = sizes
        .iter()
        .map(|&n| Dist::LogNormal { mu: 0.0, sigma: 1.0 }.sample_vec(n, n as u64))
        .collect();
    let reference: Vec<Vec<u64>> = inputs
        .iter()
        .map(|xs| {
            let mut v = xs.clone();
            v.sort_unstable_by(f64::total_cmp);
            bits(&v)
        })
        .collect();
    for backend in [par::Backend::Pool, par::Backend::Scoped] {
        par::set_backend(backend);
        for t in [1usize, 2, 8] {
            par::set_threads(t);
            for pass in 0..2 {
                for (xs, want) in inputs.iter().zip(&reference) {
                    let mut v = xs.clone();
                    par::sort::sort_f64(&mut v);
                    assert_eq!(
                        bits(&v),
                        *want,
                        "n={} pass={pass} t={t} on {backend:?}",
                        xs.len()
                    );
                }
            }
        }
    }
}

/// The size-adaptive part granularity behind [`par::map_vec`] (up to
/// `PART_FACTOR` parts per worker, bounded below by a minimum part size)
/// must be invisible in results: a map over items with wildly
/// non-uniform per-item cost — the skew the finer parts exist to absorb
/// — returns outputs in item order, bit-identical to the sequential
/// reference, at every width × backend × SIMD cell; and the ragged
/// `map_chunks` wrapper built on it likewise.
#[test]
fn non_uniform_map_vec_bit_identical_across_widths() {
    let _guard = WIDTH_LOCK.lock().unwrap();
    let _restore = ParGuard::pin();
    // Per-item cost spans ~4 orders of magnitude (a few stragglers
    // dominate) — the shape where a coarse part-per-thread split stalls
    // one worker and tempts dynamic stealing, which would reorder.
    let works: Vec<(u64, usize)> = (0..203u64)
        .map(|j| (j, if j % 67 == 0 { 40_000 } else { 5 + (j as usize % 29) }))
        .collect();
    let eval = |(seed, iters): (u64, usize)| {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut acc = 0.0f64;
        for _ in 0..iters {
            acc += rng.next_f64().sqrt();
        }
        acc.to_bits()
    };
    let xs = Dist::LogNormal { mu: 0.0, sigma: 1.0 }.sample_vec(D, 0xFEED);
    par::set_backend(par::Backend::Scoped);
    par::set_threads(1);
    par::simd::set_simd(par::simd::SimdMode::Scalar);
    let reference = par::map_vec(works.clone(), eval);
    assert_eq!(
        reference,
        works.iter().copied().map(eval).collect::<Vec<_>>(),
        "width 1 must equal the plain sequential map"
    );
    let chunk_ref: Vec<u64> = xs.chunks(1000).map(|c| c.iter().sum::<f64>().to_bits()).collect();
    for_each_exec_cell(&[1, 2, 4, 8], |cell| {
        assert_eq!(
            par::map_vec(works.clone(), eval),
            reference,
            "non-uniform map_vec diverged at cell [{cell}]"
        );
        assert_eq!(
            par::map_chunks(&xs, 1000, |_, c| c.iter().sum::<f64>().to_bits()),
            chunk_ref,
            "ragged map_chunks diverged at cell [{cell}]"
        );
    });
}

/// Decode is the inverse of encode under any width, and dequantize
/// round-trips through the parallel paths.
#[test]
fn codec_roundtrip_under_parallel_widths() {
    let _guard = WIDTH_LOCK.lock().unwrap();
    let prev = par::threads();
    let xs = Dist::Exponential { lambda: 1.0 }.sample_vec(D, 0xABCD);
    let sol = solve_hist(&xs, 16, &HistConfig::fixed(300)).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(3);
    let idx = sq::quantize(&xs, &sol.q, &mut rng);
    for t in [1usize, 3, 8] {
        par::set_threads(t);
        let c = sq::encode(&idx, &sol.q);
        let (back, qs) = sq::decode(&c);
        assert_eq!(back, idx, "t={t}");
        let vals = sq::dequantize(&back, &qs);
        assert!(vals.iter().all(|v| sol.q.contains(v)), "t={t}");
    }
    par::set_threads(prev);
}
