//! The shard layer's determinism contract, tested end to end: splitting
//! one vector across 1/2/4/8 shard ranges — on either execution backend
//! (persistent pool vs scoped spawning), at several executor widths, and
//! under either SIMD mode (scalar vs AVX2 chunk kernels, when available)
//! — must leave the merged histogram, the chosen level set, and the
//! encoded payload **bitwise-identical** to the single-node solve, on
//! every `dist::paper_suite()` family. The matrix tests walk the full
//! `threads × backend × simd` cross product through
//! `testutil::for_each_exec_cell` (shard count is the extra, file-local
//! axis), so a red cell names its exact configuration. This is the
//! `coordinator::shard` counterpart of `tests/par_invariance.rs`: thread
//! count, backend, SIMD mode, and shard count are all invisible in
//! results.
//!
//! Tests here pin the process-global executor width/backend, so they all
//! serialize on one lock (same pattern as par_invariance;
//! `for_each_exec_cell` only ever takes its own inner lock, so nesting it
//! under `WIDTH_LOCK` is deadlock-free).

use quiver::avq::histogram::{solve_hist, GridHistogram, HistConfig};
use quiver::coordinator::shard::{build_sharded, ShardConfig, ShardCoordinator};
use quiver::dist::Dist;
use quiver::par;
use quiver::sq;
use quiver::testutil::for_each_exec_cell;
use quiver::util::rng::Xoshiro256pp;

/// Crosses several chunk boundaries and ends in a ragged tail.
const D: usize = 3 * par::CHUNK + 1234;

/// Serializes tests that pin the global executor width/backend.
static WIDTH_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Restores width, backend, and SIMD mode even if an assertion panics.
struct ParGuard {
    width: usize,
    backend: par::Backend,
    simd: par::simd::SimdMode,
}

impl ParGuard {
    fn pin() -> Self {
        Self { width: par::threads(), backend: par::backend(), simd: par::simd::simd() }
    }
}

impl Drop for ParGuard {
    fn drop(&mut self) {
        par::set_threads(self.width);
        par::set_backend(self.backend);
        par::simd::set_simd(self.simd);
    }
}

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Everything the sharded build produces, in bit-exact form.
fn hist_snapshot(h: &GridHistogram) -> (Vec<u64>, Vec<u64>, u64, u64, u64, usize) {
    (
        bits(&h.weights),
        bits(&h.grid),
        h.norm2_sq.to_bits(),
        h.lo.to_bits(),
        h.hi.to_bits(),
        h.d,
    )
}

#[test]
fn merged_histogram_bitwise_identical_across_shard_counts_and_backends() {
    let _guard = WIDTH_LOCK.lock().unwrap();
    let _restore = ParGuard::pin();
    for (name, dist) in Dist::paper_suite() {
        let xs = dist.sample_vec(D, 0x5AAD);
        // Single-node reference under forced-scalar kernels; every matrix
        // cell × shard count below must reproduce it bit for bit.
        par::simd::set_simd(par::simd::SimdMode::Scalar);
        let mut ref_rng = Xoshiro256pp::seed_from_u64(0xD17E);
        let reference = hist_snapshot(&GridHistogram::build(&xs, 777, &mut ref_rng).unwrap());
        for_each_exec_cell(&[1, 2, 4], |cell| {
            for shards in [1usize, 2, 4, 8] {
                let mut rng = Xoshiro256pp::seed_from_u64(0xD17E);
                let h = build_sharded(&xs, 777, &mut rng, shards).unwrap();
                assert_eq!(
                    hist_snapshot(&h),
                    reference,
                    "{name}: histogram diverged at {shards} shards, cell [{cell}]"
                );
            }
        });
    }
}

#[test]
fn levels_and_payload_bitwise_identical_across_shard_counts_and_backends() {
    let _guard = WIDTH_LOCK.lock().unwrap();
    let _restore = ParGuard::pin();
    for (name, dist) in Dist::paper_suite() {
        let xs = dist.sample_vec(D, 0xC0FFEE);
        // Single-node reference: solve + compress, exactly as the service
        // does it (HistConfig::fixed and ShardConfig share defaults).
        par::simd::set_simd(par::simd::SimdMode::Scalar);
        let ref_sol = solve_hist(&xs, 16, &HistConfig::fixed(777)).unwrap();
        let mut ref_rng = Xoshiro256pp::seed_from_u64(0xBEEF);
        let ref_compressed = sq::compress(&xs, &ref_sol.q, &mut ref_rng);
        for_each_exec_cell(&[1, 4], |cell| {
            for shards in [1usize, 2, 4, 8] {
                let coord = ShardCoordinator::new(ShardConfig {
                    shards,
                    m: 777,
                    ..Default::default()
                });
                let mut rng = Xoshiro256pp::seed_from_u64(0xBEEF);
                let (sol, compressed) = coord.compress(&xs, 16, &mut rng).unwrap();
                let ctx = format!("{name}: {shards} shards, cell [{cell}]");
                assert_eq!(sol.q_idx, ref_sol.q_idx, "levels positions — {ctx}");
                assert_eq!(bits(&sol.q), bits(&ref_sol.q), "level values — {ctx}");
                assert_eq!(
                    sol.mse.to_bits(),
                    ref_sol.mse.to_bits(),
                    "objective — {ctx}"
                );
                assert_eq!(compressed, ref_compressed, "payload — {ctx}");
            }
        });
    }
}

#[test]
fn more_shards_than_chunks_and_tiny_inputs() {
    let _guard = WIDTH_LOCK.lock().unwrap();
    let _restore = ParGuard::pin();
    par::set_threads(4);
    // Inputs from a single element up to one chunk: with 8 shards most
    // shard ranges are empty, and the result must not care.
    for d in [1usize, 2, 100, par::CHUNK - 1, par::CHUNK] {
        let xs = Dist::Exponential { lambda: 1.0 }.sample_vec(d, 900 + d as u64);
        let mut r1 = Xoshiro256pp::seed_from_u64(5);
        let want = hist_snapshot(&GridHistogram::build(&xs, 64, &mut r1).unwrap());
        let mut r2 = Xoshiro256pp::seed_from_u64(5);
        let got = hist_snapshot(&build_sharded(&xs, 64, &mut r2, 8).unwrap());
        assert_eq!(got, want, "d={d} with 8 shards");
        // And the full compress path.
        let coord =
            ShardCoordinator::new(ShardConfig { shards: 8, m: 64, ..Default::default() });
        let sol = solve_hist(&xs, 4, &HistConfig::fixed(64)).unwrap();
        let mut q1 = Xoshiro256pp::seed_from_u64(6);
        let want_c = sq::compress(&xs, &sol.q, &mut q1);
        let mut q2 = Xoshiro256pp::seed_from_u64(6);
        let (_, got_c) = coord.compress(&xs, 4, &mut q2).unwrap();
        assert_eq!(got_c, want_c, "compress d={d} with 8 shards");
    }
}

#[test]
fn degenerate_and_error_inputs_shard_like_single_node() {
    let _guard = WIDTH_LOCK.lock().unwrap();
    let _restore = ParGuard::pin();
    par::set_threads(2);
    // Constant input: both paths collapse to the single-point grid.
    let xs = vec![1.5; 2 * par::CHUNK + 7];
    let mut r1 = Xoshiro256pp::seed_from_u64(11);
    let want = hist_snapshot(&GridHistogram::build(&xs, 32, &mut r1).unwrap());
    let mut r2 = Xoshiro256pp::seed_from_u64(11);
    let got = hist_snapshot(&build_sharded(&xs, 32, &mut r2, 4).unwrap());
    assert_eq!(got, want);
    // The compress of a constant vector is a zero-bit payload either way.
    let coord = ShardCoordinator::new(ShardConfig { shards: 4, m: 32, ..Default::default() });
    let mut q = Xoshiro256pp::seed_from_u64(12);
    let (sol, c) = coord.compress(&xs, 4, &mut q).unwrap();
    assert_eq!(sol.q, vec![1.5]);
    assert_eq!(c.bits, 0);
    assert!(c.payload.is_empty());
    assert_eq!(c.d as usize, xs.len());
    // NaN anywhere in any shard errors exactly like single-node.
    let mut bad = xs.clone();
    bad[par::CHUNK + 3] = f64::NAN;
    let mut r3 = Xoshiro256pp::seed_from_u64(13);
    assert_eq!(
        build_sharded(&bad, 32, &mut r3, 4).unwrap_err(),
        GridHistogram::build(&bad, 32, &mut Xoshiro256pp::seed_from_u64(13)).unwrap_err()
    );
}
