//! Integration: PJRT-executed artifacts must reproduce the golden dumps
//! written by `python/compile/aot.py` (which themselves are the pure-jnp
//! oracle outputs). This is the cross-language seam test: jax/Pallas
//! lowering → HLO text → xla-crate parse/compile/execute.
//!
//! Requires `make artifacts`; tests self-skip when artifacts are missing
//! so `cargo test` stays runnable from a clean checkout.

use quiver::runtime::{Runtime, Tensor};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn golden_f32(name: &str) -> Vec<f32> {
    let path = artifacts_dir().join("golden").join(format!("{name}.bin"));
    let bytes = std::fs::read(&path).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

fn golden_i32(name: &str) -> Vec<i32> {
    let path = artifacts_dir().join("golden").join(format!("{name}.bin"));
    let bytes = std::fs::read(&path).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

fn runtime_or_skip() -> Option<Runtime> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("skipping: built without the `pjrt` feature");
        return None;
    }
    if !artifacts_dir().join("manifest.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(artifacts_dir()).expect("runtime"))
}

#[test]
fn sq_artifact_matches_golden() {
    let Some(rt) = runtime_or_skip() else { return };
    let x = golden_f32("sq_x");
    let qs = golden_f32("sq_qs");
    let u = golden_f32("sq_u");
    let out = rt
        .call("sq_d1024_s8", &[Tensor::F32(x), Tensor::F32(qs), Tensor::F32(u)])
        .expect("execute sq");
    let xhat = out[0].as_f32().unwrap();
    let idx = out[1].as_i32().unwrap();
    let want_xhat = golden_f32("sq_xhat");
    let want_idx = golden_i32("sq_idx");
    assert_eq!(xhat.len(), 1024);
    for i in 0..1024 {
        assert_eq!(xhat[i], want_xhat[i], "xhat[{i}]");
        assert_eq!(idx[i], want_idx[i], "idx[{i}]");
    }
}

#[test]
fn hist_artifact_matches_golden() {
    let Some(rt) = runtime_or_skip() else { return };
    let x = golden_f32("hist_x");
    let u = golden_f32("hist_u");
    let lohi = golden_f32("hist_lohi");
    let out = rt
        .call("hist_d65536_m256", &[Tensor::F32(x), Tensor::F32(u)])
        .expect("execute hist");
    let w = out[0].as_f32().unwrap();
    let lo = out[1].as_f32().unwrap();
    let hi = out[2].as_f32().unwrap();
    let want_w = golden_f32("hist_w");
    assert_eq!(w.len(), 257);
    assert_eq!(w, &want_w[..], "weights");
    assert_eq!(lo[0], lohi[0]);
    assert_eq!(hi[0], lohi[1]);
    let total: f32 = w.iter().sum();
    assert_eq!(total, 65536.0);
}

#[test]
fn model_grad_matches_golden() {
    let Some(rt) = runtime_or_skip() else { return };
    let flat = golden_f32("model_flat");
    let xb = golden_f32("model_xb");
    let yb = golden_i32("model_yb");
    let out = rt
        .call("model_grad", &[Tensor::F32(flat), Tensor::F32(xb), Tensor::I32(yb)])
        .expect("execute model_grad");
    let loss = out[0].scalar_f32().unwrap();
    let grad = out[1].as_f32().unwrap();
    let want_loss = golden_f32("model_loss")[0];
    let want_grad = golden_f32("model_grad");
    assert!(
        (loss - want_loss).abs() < 1e-5 * want_loss.abs().max(1.0),
        "loss {loss} vs {want_loss}"
    );
    assert_eq!(grad.len(), want_grad.len());
    let mut max_abs = 0f32;
    for (g, w) in grad.iter().zip(&want_grad) {
        max_abs = max_abs.max((g - w).abs());
    }
    assert!(max_abs < 1e-5, "max grad deviation {max_abs}");
}

#[test]
fn model_init_blob_matches_golden_params() {
    if !artifacts_dir().join("manifest.txt").exists() {
        return;
    }
    let bytes = std::fs::read(artifacts_dir().join("model_init.bin")).expect("model_init.bin");
    let init: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    assert!(init.iter().all(|v| v.is_finite()));
    let flat = golden_f32("model_flat");
    assert_eq!(init, flat);
}

#[test]
fn model_eval_runs_and_is_consistent() {
    let Some(rt) = runtime_or_skip() else { return };
    let flat = golden_f32("model_flat");
    let xb = golden_f32("model_xb");
    let yb = golden_i32("model_yb");
    let out = rt
        .call("model_eval", &[Tensor::F32(flat), Tensor::F32(xb), Tensor::I32(yb)])
        .expect("execute model_eval");
    let loss = out[0].scalar_f32().unwrap();
    let acc = out[1].scalar_f32().unwrap();
    let want_loss = golden_f32("model_loss")[0];
    assert!((loss - want_loss).abs() < 1e-5 * want_loss.abs().max(1.0));
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn call_validates_signatures() {
    let Some(rt) = runtime_or_skip() else { return };
    // Wrong arity.
    assert!(rt.call("sq_d1024_s8", &[]).is_err());
    // Wrong dtype.
    let bad = rt.call(
        "sq_d1024_s8",
        &[
            Tensor::I32(vec![0; 1024]),
            Tensor::F32(vec![0.0; 8]),
            Tensor::F32(vec![0.0; 1024]),
        ],
    );
    assert!(bad.is_err());
    // Unknown artifact.
    assert!(rt.call("nope", &[]).is_err());
}

#[test]
fn runtime_handle_service_thread() {
    if cfg!(not(feature = "pjrt")) || !artifacts_dir().join("manifest.txt").exists() {
        return;
    }
    let h = quiver::runtime::exec::RuntimeHandle::spawn(artifacts_dir()).expect("spawn");
    assert_eq!(h.platform().unwrap(), "cpu");
    h.warmup("sq_d1024_s8").unwrap();
    // Concurrent callers through clones of the handle.
    let mut joins = vec![];
    for t in 0..4 {
        let h = h.clone();
        joins.push(std::thread::spawn(move || {
            let x = golden_f32("sq_x");
            let qs = golden_f32("sq_qs");
            let u = golden_f32("sq_u");
            let out = h
                .call("sq_d1024_s8", vec![Tensor::F32(x), Tensor::F32(qs), Tensor::F32(u)])
                .unwrap_or_else(|e| panic!("thread {t}: {e:#}"));
            out[0].as_f32().unwrap().to_vec()
        }));
    }
    let results: Vec<Vec<f32>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let want = golden_f32("sq_xhat");
    for r in results {
        assert_eq!(r, want);
    }
}

#[test]
fn unbiasedness_through_the_full_stack() {
    // Statistical seam test: executing the sq artifact with many uniform
    // draws generated in Rust must average back to x.
    let Some(rt) = runtime_or_skip() else { return };
    use quiver::util::rng::Xoshiro256pp;
    let x = golden_f32("sq_x");
    let qs = golden_f32("sq_qs");
    let mut rng = Xoshiro256pp::seed_from_u64(4242);
    let trials = 64;
    let mut acc = vec![0f64; x.len()];
    for _ in 0..trials {
        let u: Vec<f32> = (0..x.len()).map(|_| rng.next_f32()).collect();
        let out = rt
            .call(
                "sq_d1024_s8",
                &[Tensor::F32(x.clone()), Tensor::F32(qs.clone()), Tensor::F32(u)],
            )
            .unwrap();
        for (a, v) in acc.iter_mut().zip(out[0].as_f32().unwrap()) {
            *a += *v as f64;
        }
    }
    let span = (qs[qs.len() - 1] - qs[0]) as f64;
    let mut worst = 0.0f64;
    for (a, &xi) in acc.iter().zip(&x) {
        let mean = a / trials as f64;
        worst = worst.max((mean - xi as f64).abs());
    }
    assert!(
        worst < 0.2 * span,
        "worst per-coordinate deviation {worst} vs span {span}"
    );
}
