//! Chaos suite: every [`FaultAction`] driven against a live shard fleet
//! through the deterministic fault proxy (`coordinator::faultnet`), plus
//! mid-ingest request-direction faults against a live compression service
//! (drop/truncate/stall during a chunked `coordinator::ingest` upload),
//! plus client-misbehaviour chaos against the epoll serving front-end
//! (slow-loris, half-open idle connections, over-budget floods — see the
//! `epoll_chaos` module at the bottom).
//!
//! The contract under test (DESIGN.md rule 7): whatever the failure —
//! refused connect, mid-phase kill, stall, truncated frame, corrupt
//! frame — the fault-tolerant coordinator either recovers a result that
//! is **bitwise identical** to the healthy single-process run, or fails
//! with a clean typed error, always before the configured deadlines.
//! Never a hang, never silently wrong bits, and the caller's RNG
//! advances identically on every path (so recovery is invisible to
//! everything downstream).

use std::time::{Duration, Instant};

use quiver::coordinator::fault::{FleetConfig, FleetState};
use quiver::coordinator::faultnet::{FaultAction, FaultProxy, FaultSchedule};
use quiver::coordinator::ingest::{self, IngestConfig};
use quiver::coordinator::protocol::{recv, send, Msg};
use quiver::coordinator::router::{Router, RouterConfig};
use quiver::coordinator::service::{ingest_remote, Service, ServiceConfig};
use quiver::coordinator::shard::{ShardConfig, ShardCoordinator, ShardNode};
use quiver::dist::Dist;
use quiver::util::rng::Xoshiro256pp;

const S: usize = 8;
/// Seed of the caller-side quantize RNG — shared by the reference run and
/// every fleet run so bit-equality is meaningful.
const SEED: u64 = 0xFA17;

/// A chunk-crossing input, so re-planning actually moves chunk ranges
/// between nodes (the invariance being exercised).
fn sample() -> Vec<f64> {
    Dist::LogNormal { mu: 0.0, sigma: 0.8 }.sample_vec(2 * quiver::par::CHUNK + 345, 21)
}

fn coord() -> ShardCoordinator {
    ShardCoordinator::new(ShardConfig { m: 96, ..Default::default() })
}

/// Short deadlines and a small retry budget: every fault class must
/// resolve in seconds, not default-production minutes.
fn short_net() -> FleetConfig {
    FleetConfig {
        connect_timeout: Duration::from_millis(500),
        io_timeout: Duration::from_millis(1000),
        retries: 1,
        retry_backoff: Duration::from_millis(10),
        ..Default::default()
    }
}

/// An address that refuses connections: bind an ephemeral port, then
/// drop the listener.
fn dead_addr() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    l.local_addr().unwrap().to_string()
}

/// `schedules.len()` shard nodes, each behind its own fault proxy.
struct Fleet {
    nodes: Vec<ShardNode>,
    proxies: Vec<FaultProxy>,
}

impl Fleet {
    fn start(schedules: Vec<FaultSchedule>) -> Self {
        let (mut nodes, mut proxies) = (Vec::new(), Vec::new());
        for schedule in schedules {
            let node = ShardNode::start("127.0.0.1:0").unwrap();
            let proxy = FaultProxy::start(node.addr(), schedule).unwrap();
            nodes.push(node);
            proxies.push(proxy);
        }
        Self { nodes, proxies }
    }

    fn addrs(&self) -> Vec<String> {
        self.proxies.iter().map(|p| p.addr().to_string()).collect()
    }

    fn shutdown(self) {
        for p in self.proxies {
            p.shutdown();
        }
        for n in self.nodes {
            n.shutdown();
        }
    }
}

/// The healthy single-process run every recovery must reproduce.
fn reference(xs: &[f64]) -> (quiver::avq::Solution, quiver::sq::CompressedVec) {
    let mut rng = Xoshiro256pp::seed_from_u64(SEED);
    coord().compress(xs, S, &mut rng).unwrap()
}

/// Drive the fleet path and assert the full recovery contract: same bits
/// as the healthy reference, bounded wall clock, and exactly one caller
/// RNG draw consumed.
fn assert_recovers_bitwise(addrs: &[String], xs: &[f64], net: &FleetConfig, state: &FleetState) {
    let (want_sol, want_c) = reference(xs);
    let mut rng = Xoshiro256pp::seed_from_u64(SEED);
    let t0 = Instant::now();
    let (sol, c) = coord()
        .compress_remote_ft(addrs, xs, S, &mut rng, net, state)
        .expect("fleet must recover");
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "recovery must beat the deadline, took {:?}",
        t0.elapsed()
    );
    assert_eq!(sol.q_idx, want_sol.q_idx, "recovered level set must match");
    assert_eq!(c, want_c, "recovered bits must be identical to the healthy run");
    let mut ref_rng = Xoshiro256pp::seed_from_u64(SEED);
    let _ = ref_rng.next_u64();
    assert_eq!(
        rng.next_u64(),
        ref_rng.next_u64(),
        "fleet path must consume exactly one caller draw, like the healthy path"
    );
}

#[test]
fn connect_refused_node_replans_bitwise() {
    let fleet = Fleet::start(vec![FaultSchedule::transparent(); 2]);
    let mut addrs = vec![dead_addr()];
    addrs.extend(fleet.addrs());
    let xs = sample();
    let net = short_net();
    let state = FleetState::new(&net);
    assert_recovers_bitwise(&addrs, &xs, &net, &state);
    let (faults, retries, _, fallbacks) = state.stats.snapshot();
    assert!(faults >= 1, "the refused connect must be counted as a fault");
    assert!(retries >= 1, "connect retry and/or re-plan must be counted");
    assert_eq!(fallbacks, 0, "two healthy nodes remain — no local fallback");
    fleet.shutdown();
}

#[test]
fn mid_phase_drop_replans_bitwise_over_survivors() {
    // Node 0 dies *mid-task*: it serves the scan reply (one frame), then
    // the connection drops before the histogram phase — the degraded-mode
    // equivalence case (kill 1 of 3 after phase 1).
    let fleet = Fleet::start(vec![
        FaultSchedule::all(FaultAction::DropAfterFrames(1)),
        FaultSchedule::transparent(),
        FaultSchedule::transparent(),
    ]);
    let xs = sample();
    let net = short_net();
    let state = FleetState::new(&net);
    assert_recovers_bitwise(&fleet.addrs(), &xs, &net, &state);
    let (faults, retries, _, fallbacks) = state.stats.snapshot();
    assert!(faults >= 1, "the mid-phase drop must be counted");
    assert!(retries >= 1, "the re-plan must be counted");
    assert_eq!(fallbacks, 0);
    fleet.shutdown();
}

#[test]
fn stalled_node_times_out_and_replans_bitwise() {
    // Node 1 accepts, then goes silent holding the connection open: only
    // the io deadline can unblock the coordinator.
    let fleet = Fleet::start(vec![
        FaultSchedule::transparent(),
        FaultSchedule::all(FaultAction::StallAfterFrames(0)),
        FaultSchedule::transparent(),
    ]);
    let xs = sample();
    let net = short_net();
    let state = FleetState::new(&net);
    assert_recovers_bitwise(&fleet.addrs(), &xs, &net, &state);
    let (faults, ..) = state.stats.snapshot();
    assert!(faults >= 1, "the stall must surface as a classified timeout fault");
    fleet.shutdown();
}

#[test]
fn truncated_frame_replans_bitwise() {
    // Node 2's first reply frame announces its full length but carries
    // half the bytes: a clean UnexpectedEof, then re-plan.
    let fleet = Fleet::start(vec![
        FaultSchedule::transparent(),
        FaultSchedule::transparent(),
        FaultSchedule::all(FaultAction::TruncateFrame(0)),
    ]);
    let xs = sample();
    let net = short_net();
    let state = FleetState::new(&net);
    assert_recovers_bitwise(&fleet.addrs(), &xs, &net, &state);
    let (faults, ..) = state.stats.snapshot();
    assert!(faults >= 1, "the truncated frame must be counted");
    fleet.shutdown();
}

#[test]
fn corrupt_frame_fails_loudly_and_replans_bitwise() {
    // Node 0's first reply frame arrives with a poisoned tag byte: the
    // codec must reject it (InvalidData) — corruption is never allowed to
    // decode into silently wrong statistics.
    let fleet = Fleet::start(vec![
        FaultSchedule::all(FaultAction::CorruptFrame(0)),
        FaultSchedule::transparent(),
        FaultSchedule::transparent(),
    ]);
    let xs = sample();
    let net = short_net();
    let state = FleetState::new(&net);
    assert_recovers_bitwise(&fleet.addrs(), &xs, &net, &state);
    let (faults, ..) = state.stats.snapshot();
    assert!(faults >= 1, "the corrupt frame must be counted");
    fleet.shutdown();
}

#[test]
fn slow_but_correct_fleet_needs_no_recovery() {
    // Per-frame delay well under the io deadline: the run is slower but
    // fault-free, and of course bit-identical.
    let fleet = Fleet::start(vec![FaultSchedule::all(FaultAction::DelayMs(25)); 3]);
    let xs = sample();
    let net = short_net();
    let state = FleetState::new(&net);
    assert_recovers_bitwise(&fleet.addrs(), &xs, &net, &state);
    assert_eq!(
        state.stats.snapshot(),
        (0, 0, 0, 0),
        "a slow-but-correct fleet must not be charged any fault"
    );
    fleet.shutdown();
}

#[test]
fn exhausted_fleet_falls_back_locally_bitwise() {
    // Every node is dead: after the bounded retries the coordinator must
    // fall back to the in-process solve — same bits, counted as a
    // fallback, still no hang.
    let addrs = vec![dead_addr(), dead_addr()];
    let xs = sample();
    let net = FleetConfig { retries: 0, ..short_net() };
    let state = FleetState::new(&net);
    assert_recovers_bitwise(&addrs, &xs, &net, &state);
    let (faults, _, _, fallbacks) = state.stats.snapshot();
    assert!(faults >= 2, "both dead nodes must be counted");
    assert_eq!(fallbacks, 1, "exactly one local fallback");
}

#[test]
fn breaker_skips_persistently_dead_node_across_calls() {
    // A shared FleetState across calls: the dead node is charged until
    // the breaker opens, after which calls skip it up front (no connect
    // latency) and still produce identical bits from the survivor.
    let fleet = Fleet::start(vec![FaultSchedule::transparent()]);
    let mut addrs = vec![dead_addr()];
    addrs.extend(fleet.addrs());
    let xs = sample();
    let net = FleetConfig {
        retries: 0,
        breaker_threshold: 2,
        breaker_cooldown: 100, // far beyond this test: no half-open probe
        ..short_net()
    };
    let state = FleetState::new(&net);
    for call in 0..4 {
        assert_recovers_bitwise(&addrs, &xs, &net, &state);
        let (_, _, skips, _) = state.stats.snapshot();
        if call < 2 {
            assert_eq!(skips, 0, "breaker must stay closed below the threshold");
        }
    }
    let (faults, _, skips, fallbacks) = state.stats.snapshot();
    assert_eq!(faults, 2, "charged only until the breaker opened");
    assert_eq!(skips, 2, "calls 3 and 4 skip the dead node up front");
    assert_eq!(fallbacks, 0);
    fleet.shutdown();
}

#[test]
fn non_finite_input_is_a_fast_typed_error_not_a_node_fault() {
    // A hard input error through a healthy fleet: no amount of retrying
    // fixes NaN, so it must come back as an error immediately, with no
    // node charged and no fallback attempted.
    let fleet = Fleet::start(vec![FaultSchedule::transparent(); 2]);
    let mut xs = sample();
    xs[quiver::par::CHUNK + 3] = f64::NAN;
    let net = short_net();
    let state = FleetState::new(&net);
    let mut rng = Xoshiro256pp::seed_from_u64(SEED);
    let t0 = Instant::now();
    let err = coord()
        .compress_remote_ft(&fleet.addrs(), &xs, S, &mut rng, &net, &state)
        .expect_err("NaN input must fail");
    assert!(t0.elapsed() < Duration::from_secs(30));
    assert!(err.to_string().contains("non-finite"), "typed cause: {err:#}");
    assert_eq!(state.stats.snapshot(), (0, 0, 0, 0), "hard errors charge no node");
    fleet.shutdown();
}

// ---------------------------------------------------------------------------
// Mid-ingest chaos: faults injected on the *request* direction, where the
// chunked uploads of `coordinator::ingest` live. The contract extends rule 7
// to ingestion — a faulted upload fails cleanly (typed error or EOF, partial
// state freed with the connection), and every later tenant is bit-identical
// to the healthy monolithic reference.
// ---------------------------------------------------------------------------

const INGEST_M: usize = 96;

/// A ragged two-chunk ingest input (mirrors `sample()` but f32, the wire
/// element type of ingestion).
fn fsample(seed: u64) -> Vec<f32> {
    Dist::LogNormal { mu: 0.0, sigma: 0.8 }
        .sample_vec(2 * quiver::par::CHUNK + 345, seed)
        .into_iter()
        .map(|x| x as f32)
        .collect()
}

/// A service whose ingest grid matches [`INGEST_M`] (the router's `hist_m`
/// overrides the ingest grid at start-up), behind one fault proxy.
fn ingest_rig(schedule: FaultSchedule) -> (Service, FaultProxy) {
    let service = Service::start(ServiceConfig {
        threads: 2,
        router: Router::new(RouterConfig {
            exact_max_d: 4096,
            hist_m: INGEST_M,
            seed: 7,
            shards: 1,
        }),
        io_timeout: Duration::from_millis(800),
        ..Default::default()
    })
    .unwrap();
    let proxy = FaultProxy::start(service.addr(), schedule).unwrap();
    (service, proxy)
}

/// The bits every post-fault tenant must reproduce.
fn ingest_reference(data: &[f32], task_id: u64) -> quiver::sq::CompressedVec {
    let cfg = IngestConfig { m: INGEST_M, ..Default::default() };
    ingest::monolithic_reference(data, S as u32, &cfg, task_id).unwrap().0
}

/// Run a healthy ingest over `addr` and assert bitwise identity with the
/// monolithic reference for this task id.
fn assert_ingest_bitwise(addr: &str, data: &[f32], task_id: u64) {
    let (cv, _, _) = ingest_remote(addr, task_id, S as u32, 0, 0, data)
        .expect("healthy ingest must succeed");
    assert_eq!(cv, ingest_reference(data, task_id), "ingest bits must match monolithic");
}

#[test]
fn ingest_drop_after_n_chunks_fails_cleanly_then_next_tenant_matches() {
    // Conn 0 dies after IngestOpen + one chunk frame: the close never
    // arrives, the service frees the half-filled task with the connection,
    // and the client gets a clean EOF/error — never a hang, never bits.
    let (service, proxy) = ingest_rig(
        FaultSchedule::transparent()
            .with_conn(0, FaultAction::DropAfterFrames(2))
            .on_requests(),
    );
    let data = fsample(31);
    let t0 = Instant::now();
    ingest_remote(proxy.addr(), 1, S as u32, 0, 0, &data)
        .expect_err("dropped upload must fail");
    assert!(t0.elapsed() < Duration::from_secs(10), "drop must fail fast");
    // Conn 1 (same proxy, transparent) and the same task id: bit-identical.
    assert_ingest_bitwise(proxy.addr(), &data, 1);
    proxy.shutdown();
    service.shutdown();
}

#[test]
fn ingest_truncated_chunk_frame_fails_cleanly_then_next_tenant_matches() {
    // Conn 0's first IngestChunk frame (request frame 1) is cut mid-body:
    // the service's codec sees UnexpectedEof and drops the connection.
    let (service, proxy) = ingest_rig(
        FaultSchedule::transparent()
            .with_conn(0, FaultAction::TruncateFrame(1))
            .on_requests(),
    );
    let data = fsample(32);
    let t0 = Instant::now();
    ingest_remote(proxy.addr(), 4, S as u32, 0, 0, &data)
        .expect_err("truncated chunk upload must fail");
    assert!(t0.elapsed() < Duration::from_secs(10), "truncation must fail fast");
    assert_ingest_bitwise(proxy.addr(), &data, 4);
    proxy.shutdown();
    service.shutdown();
}

#[test]
fn ingest_stall_past_deadline_is_unblocked_by_the_service_io_timeout() {
    // Conn 0 stalls after IngestOpen, holding the socket open: only the
    // service-side io deadline can break the wedge. It must — the reader
    // thread disconnects, frees the opened task, and the client observes
    // a bounded EOF, not a hang (DESIGN.md rule 7 for ingestion).
    let (service, proxy) = ingest_rig(
        FaultSchedule::transparent()
            .with_conn(0, FaultAction::StallAfterFrames(1))
            .on_requests(),
    );
    let data = fsample(33);
    let t0 = Instant::now();
    ingest_remote(proxy.addr(), 9, S as u32, 0, 0, &data)
        .expect_err("stalled upload must time out server-side");
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "stall must be bounded by the io deadline, took {:?}",
        t0.elapsed()
    );
    assert_ingest_bitwise(proxy.addr(), &data, 9);
    proxy.shutdown();
    service.shutdown();
}

#[test]
fn ingest_bad_chunk_ids_get_one_busy_and_leave_other_tenants_intact() {
    // Protocol abuse straight at the service (no proxy): an out-of-range
    // chunk index and a duplicate chunk each kill their task with exactly
    // one Busy; the connection survives, and a clean task on the *same*
    // connection afterwards still produces monolithic bits.
    let service = Service::start(ServiceConfig {
        threads: 2,
        router: Router::new(RouterConfig {
            exact_max_d: 4096,
            hist_m: INGEST_M,
            seed: 7,
            shards: 1,
        }),
        ..Default::default()
    })
    .unwrap();
    let data = fsample(34);
    let (lo, hi) = ingest::declared_range(&data);
    let d = data.len() as u64;
    let n_chunks = data.len().div_ceil(quiver::par::CHUNK) as u64;

    let stream = std::net::TcpStream::connect(service.addr()).unwrap();
    let mut wr = stream.try_clone().unwrap();
    let mut rd = std::io::BufReader::new(stream);
    let open = |task_id: u64| Msg::IngestOpen {
        task_id,
        d,
        s: S as u32,
        class: 0,
        deadline_ms: 0,
        lo,
        hi,
    };

    // Task 1: out-of-range chunk index (start = 9·CHUNK ≥ d) → one Busy.
    send(&mut wr, &open(1)).unwrap();
    send(&mut wr, &Msg::IngestChunk { task_id: 1, chunk_idx: 9, data: vec![0.0; 16] }).unwrap();
    match recv(&mut rd).unwrap() {
        Some(Msg::Busy { request_id: 1 }) => {}
        other => panic!("out-of-range chunk: {other:?}"),
    }
    // The dead task answers nothing further — not even to a close.
    send(&mut wr, &Msg::IngestChunk {
        task_id: 1,
        chunk_idx: 0,
        data: ingest::chunk_of(&data, 0).to_vec(),
    })
    .unwrap();
    send(&mut wr, &Msg::IngestClose { task_id: 1 }).unwrap();

    // Task 2: the same chunk twice → one Busy.
    send(&mut wr, &open(2)).unwrap();
    let c0 = ingest::chunk_of(&data, 0).to_vec();
    send(&mut wr, &Msg::IngestChunk { task_id: 2, chunk_idx: 0, data: c0.clone() }).unwrap();
    send(&mut wr, &Msg::IngestChunk { task_id: 2, chunk_idx: 0, data: c0 }).unwrap();
    match recv(&mut rd).unwrap() {
        Some(Msg::Busy { request_id: 2 }) => {}
        other => panic!("duplicate chunk: {other:?}"),
    }

    // Task 3 on the same connection: full clean lifecycle, monolithic bits.
    send(&mut wr, &open(3)).unwrap();
    for ci in 0..n_chunks {
        send(&mut wr, &Msg::IngestChunk {
            task_id: 3,
            chunk_idx: ci,
            data: ingest::chunk_of(&data, ci).to_vec(),
        })
        .unwrap();
    }
    send(&mut wr, &Msg::IngestClose { task_id: 3 }).unwrap();
    let levels = match recv(&mut rd).unwrap() {
        Some(Msg::IngestSolved { task_id: 3, levels, .. }) => levels,
        other => panic!("clean task must solve (exactly one Busy per dead task): {other:?}"),
    };
    let mut payload = Vec::new();
    for ci in 0..n_chunks {
        send(&mut wr, &Msg::IngestChunk {
            task_id: 3,
            chunk_idx: ci,
            data: ingest::chunk_of(&data, ci).to_vec(),
        })
        .unwrap();
        match recv(&mut rd).unwrap() {
            Some(Msg::IngestPayloadChunk { task_id: 3, chunk_idx, payload: part, .. }) => {
                assert_eq!(chunk_idx, ci, "payload windows arrive in lock-step order");
                payload.extend_from_slice(&part);
            }
            other => panic!("payload window: {other:?}"),
        }
    }
    let bits = quiver::sq::codec::bits_for(levels.len());
    let got = quiver::sq::CompressedVec { d, q: levels, bits, payload };
    assert_eq!(got, ingest_reference(&data, 3), "post-abuse tenant must match monolithic");
    service.shutdown();
}

// ---------------------------------------------------------------------------
// Epoll front-end chaos: misbehaving *clients* against the event loop.
// The contract extends rule 7 to the serving front-end — a slow-loris
// writer, a half-open idle connection, or an over-budget flood is shed or
// timed out with a typed outcome (disconnect or `Busy`, counted in the
// stats), and healthy tenants sharing the same I/O threads keep getting
// replies bit-identical to an undisturbed threaded-front-end control.
// Linux-only, like the event loop itself.
// ---------------------------------------------------------------------------

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod epoll_chaos {
    use super::*;
    use std::io::{Read, Write};
    use std::net::TcpStream;

    use quiver::coordinator::eventloop::BudgetConfig;
    use quiver::coordinator::service::{compress_remote, stats_remote, Frontend};

    fn router() -> Router {
        Router::new(RouterConfig { exact_max_d: 4096, hist_m: INGEST_M, seed: 7, shards: 1 })
    }

    /// The undisturbed threaded-front-end control every healthy tenant's
    /// reply is compared against, bit for bit.
    fn control() -> Service {
        Service::start(ServiceConfig {
            threads: 2,
            frontend: Frontend::Threads,
            router: router(),
            ..Default::default()
        })
        .unwrap()
    }

    fn epoll_service(io_timeout: Duration, queue_capacity: usize, budgets: BudgetConfig) -> Service {
        Service::start(ServiceConfig {
            threads: 2,
            queue_capacity,
            frontend: Frontend::Epoll,
            io_timeout,
            budgets,
            router: router(),
            ..Default::default()
        })
        .unwrap()
    }

    fn fvec(d: usize, seed: u64) -> Vec<f32> {
        Dist::LogNormal { mu: 0.0, sigma: 0.8 }
            .sample_vec(d, seed)
            .into_iter()
            .map(|x| x as f32)
            .collect()
    }

    /// The deterministic reply fields (`solve_us` is wall time).
    fn reply_bits(msg: Msg) -> (quiver::sq::CompressedVec, String) {
        match msg {
            Msg::CompressReply { compressed, solver, .. } => (compressed, solver),
            other => panic!("expected CompressReply, got {}", other.kind()),
        }
    }

    /// Wait (bounded) for the server to close `sock`: a clean FIN reads
    /// as `Ok(0)`, a reset as `ConnectionReset` — either is a typed
    /// disconnect; anything else (data, hang) fails the test.
    fn expect_server_close(sock: &mut TcpStream, within: Duration) {
        sock.set_read_timeout(Some(within)).unwrap();
        let t0 = Instant::now();
        let mut buf = [0u8; 16];
        match sock.read(&mut buf) {
            Ok(0) => {}
            Ok(n) => panic!("server sent {n} unexpected bytes instead of closing"),
            Err(e) => assert!(
                matches!(
                    e.kind(),
                    std::io::ErrorKind::ConnectionReset | std::io::ErrorKind::BrokenPipe
                ),
                "expected a bounded disconnect, got: {e}"
            ),
        }
        assert!(t0.elapsed() < within, "close must beat the read deadline");
    }

    #[test]
    fn slow_loris_is_reaped_and_healthy_tenant_unaffected() {
        let control = control();
        let epoll = epoll_service(Duration::from_millis(300), 64, BudgetConfig::default());
        // The loris announces a 1000-byte frame, delivers 3 bytes, then
        // goes silent holding the socket open — the classic attack shape
        // that pins one thread forever under thread-per-connection.
        let mut loris = TcpStream::connect(epoll.addr()).unwrap();
        loris.write_all(&1000u32.to_le_bytes()).unwrap();
        loris.write_all(&[10, 0, 0]).unwrap();
        // A healthy tenant *during* the stall: served immediately (the
        // loris pins no thread) and bit-identical to the control.
        let data = fvec(700, 41);
        let got = reply_bits(compress_remote(epoll.addr(), 5, S as u32, &data).unwrap());
        let want = reply_bits(compress_remote(control.addr(), 5, S as u32, &data).unwrap());
        assert_eq!(got, want, "healthy tenant diverged during a loris stall");
        // The mid-frame sweep disconnects the loris once the partial
        // frame outlives the io deadline — bounded, typed, counted.
        expect_server_close(&mut loris, Duration::from_secs(8));
        let snap = stats_remote(epoll.addr(), 77).unwrap();
        assert!(snap.slow_clients >= 1, "the loris must be counted as a slow client");
        control.shutdown();
        epoll.shutdown();
    }

    #[test]
    fn half_open_idle_conn_is_reaped_within_deadline() {
        let epoll = epoll_service(Duration::from_millis(300), 64, BudgetConfig::default());
        // Connect and never send a byte: a half-open peer (pulled cable,
        // dead NAT entry). Only the idle sweep can reclaim the slot.
        let mut idle = TcpStream::connect(epoll.addr()).unwrap();
        expect_server_close(&mut idle, Duration::from_secs(8));
        // An idle reap is a connection *fault*, not a slow client: the
        // slow-client counter stays untouched.
        let snap = stats_remote(epoll.addr(), 78).unwrap();
        assert_eq!(snap.slow_clients, 0, "idle reap must not be misclassified as slow");
        epoll.shutdown();
    }

    #[test]
    fn over_budget_flood_pauses_reads_without_losing_requests() {
        let control = control();
        // A 2-request in-flight budget: the flood crosses it immediately,
        // the loop parks the connection's EPOLLIN subscription, and
        // resumes as replies retire tickets — throttled, never dropped.
        let budgets = BudgetConfig { max_conn_requests: 2, ..Default::default() };
        let epoll = epoll_service(Duration::from_secs(30), 64, budgets);
        const N: u64 = 24;
        let sock = TcpStream::connect(epoll.addr()).unwrap();
        let mut wr = sock.try_clone().unwrap();
        let mut rd = std::io::BufReader::new(sock);
        for rid in 0..N {
            let req = Msg::CompressRequest {
                request_id: rid,
                s: S as u32,
                class: 0,
                deadline_ms: 0,
                data: fvec(400, 0xF100D + rid),
            };
            send(&mut wr, &req).unwrap();
        }
        let mut got = std::collections::BTreeMap::new();
        for _ in 0..N {
            match recv(&mut rd).unwrap() {
                Some(Msg::CompressReply { request_id, compressed, solver, .. }) => {
                    got.insert(request_id, (compressed, solver));
                }
                other => panic!("flood under budget pause must not shed: {other:?}"),
            }
        }
        // Every request answered exactly once, bit-identical to the
        // control given the same request id and bytes.
        for rid in 0..N {
            let want =
                reply_bits(compress_remote(control.addr(), rid, S as u32, &fvec(400, 0xF100D + rid)).unwrap());
            assert_eq!(got[&rid], want, "request {rid} diverged under backpressure");
        }
        control.shutdown();
        epoll.shutdown();
    }

    #[test]
    fn queue_full_flood_sheds_typed_busy_and_spares_later_tenants() {
        let control = control();
        // A one-slot scheduler queue: a pipelined burst outruns the
        // solver pool, and the overflow comes back as *typed* `Busy`
        // (correlated by request id) — never a dropped or reordered reply.
        let epoll = epoll_service(Duration::from_secs(30), 1, BudgetConfig::default());
        const N: u64 = 16;
        let sock = TcpStream::connect(epoll.addr()).unwrap();
        let mut wr = sock.try_clone().unwrap();
        let mut rd = std::io::BufReader::new(sock);
        for rid in 0..N {
            let req = Msg::CompressRequest {
                request_id: rid,
                s: S as u32,
                class: 0,
                deadline_ms: 0,
                data: fvec(3000, 0xB0257 + rid),
            };
            send(&mut wr, &req).unwrap();
        }
        let (mut solved, mut busy) = (std::collections::BTreeMap::new(), 0u64);
        for _ in 0..N {
            match recv(&mut rd).unwrap() {
                Some(Msg::CompressReply { request_id, compressed, solver, .. }) => {
                    solved.insert(request_id, (compressed, solver));
                }
                Some(Msg::Busy { .. }) => busy += 1,
                other => panic!("unexpected reply: {other:?}"),
            }
        }
        assert!(busy >= 1, "a one-slot queue under a {N}-deep burst must shed");
        assert_eq!(solved.len() as u64 + busy, N, "every request answered exactly once");
        // The requests that did get through are bit-identical to the
        // control, and a fresh tenant after the flood is too.
        for (rid, bits) in &solved {
            let want = reply_bits(
                compress_remote(control.addr(), *rid, S as u32, &fvec(3000, 0xB0257 + rid)).unwrap(),
            );
            assert_eq!(*bits, want, "request {rid} diverged under a shedding flood");
        }
        let data = fvec(900, 91);
        let got = reply_bits(compress_remote(epoll.addr(), 777, S as u32, &data).unwrap());
        let want = reply_bits(compress_remote(control.addr(), 777, S as u32, &data).unwrap());
        assert_eq!(got, want, "post-flood tenant diverged");
        control.shutdown();
        epoll.shutdown();
    }
}
