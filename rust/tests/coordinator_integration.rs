//! Loopback integration tests for the L3 coordinator: a real TCP
//! parameter-server round-trip with AVQ-compressed gradients, and the
//! compression microservice under concurrent load.

use std::time::Duration;

use quiver::coordinator::protocol::Msg;
use quiver::coordinator::router::{Router, RouterConfig};
use quiver::coordinator::server::{Server, ServerConfig};
use quiver::coordinator::service::{compress_remote, Service, ServiceConfig};
use quiver::coordinator::tasks::QuadraticToy;
use quiver::coordinator::worker::{run_worker, WorkerConfig};
use quiver::sq;

/// Federated training over loopback TCP: 4 workers on a convex toy task.
/// The loss must collapse and the uplink must be ~8× smaller than raw.
#[test]
fn federated_round_trip_converges() {
    let dim = 400;
    let workers = 4;
    let rounds = 40;
    let target: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.05).sin() * 3.0).collect();

    let server = Server::bind(ServerConfig {
        workers,
        rounds,
        dim,
        lr: 0.3,
        round_timeout: Duration::from_secs(20),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr().unwrap();

    let mut joins = vec![];
    for w in 0..workers {
        let addr = addr.clone();
        let target = target.clone();
        joins.push(std::thread::spawn(move || {
            let cfg = WorkerConfig {
                id: w as u64,
                s: 16,
                router: Router::default(),
                seed: 1000 + w as u64,
            };
            let toy = QuadraticToy::new(target, 0.01, 2000 + w as u64);
            run_worker(&addr, cfg, toy).expect("worker")
        }));
    }

    let (final_params, log) = server.run(vec![0f32; dim]).expect("server run");
    let stats: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();

    // Convergence: the quadratic's loss collapses by orders of magnitude.
    let first = log.rounds.first().unwrap().mean_loss;
    let last = log.rounds.last().unwrap().mean_loss;
    assert!(
        last < first * 0.01,
        "loss should collapse: {first} -> {last}"
    );
    for (p, t) in final_params.iter().zip(&target) {
        assert!((p - t).abs() < 0.1, "{p} vs {t}");
    }
    // Compression accounting: 4-bit codes ≈ 8× smaller than f32.
    let (compressed, raw) = log.totals();
    assert!(
        raw > 0 && compressed * 4 < raw,
        "ratio {}x",
        raw as f64 / compressed as f64
    );
    // Every round got all submissions.
    for r in &log.rounds {
        assert_eq!(r.submissions, workers);
    }
    for s in &stats {
        assert_eq!(s.rounds, rounds);
        assert!(s.bytes_sent * 4 < s.bytes_raw);
    }
}

/// A worker that vanishes after admission: the server must fail cleanly
/// (no hang) once sends fail or the round times out.
#[test]
fn server_survives_dead_worker_with_timeout() {
    let dim = 50;
    let server = Server::bind(ServerConfig {
        workers: 2,
        rounds: 5,
        dim,
        lr: 0.1,
        round_timeout: Duration::from_millis(300),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr().unwrap();

    // Worker 0: healthy.
    let a0 = addr.clone();
    let healthy = std::thread::spawn(move || {
        let cfg = WorkerConfig { id: 0, s: 4, router: Router::default(), seed: 1 };
        let toy = QuadraticToy::new(vec![1.0; 50], 0.0, 2);
        // May error when the server aborts early — either way it must return.
        let _ = run_worker(&a0, cfg, toy);
    });
    // Worker 1: says hello, then disappears.
    let a1 = addr.clone();
    let ghost = std::thread::spawn(move || {
        use quiver::coordinator::protocol::{recv, send};
        let mut s = std::net::TcpStream::connect(&a1).unwrap();
        send(&mut s, &Msg::Hello { worker_id: 1 }).unwrap();
        let mut rd = std::io::BufReader::new(s.try_clone().unwrap());
        let _ = recv(&mut rd); // Welcome
        drop(s); // vanish
    });

    let started = std::time::Instant::now();
    // With one healthy worker the server still makes progress (aggregates
    // the submissions it has) or errors cleanly — it must not hang.
    let result = server.run(vec![0f32; dim]);
    assert!(started.elapsed() < Duration::from_secs(10), "server hung");
    match result {
        Ok((_, log)) => {
            assert!(!log.rounds.is_empty());
            for r in &log.rounds {
                assert!(r.submissions >= 1);
            }
        }
        Err(e) => {
            // Acceptable: broken pipe to the ghost. Must be an error, not a hang.
            eprintln!("server errored as expected: {e:#}");
        }
    }
    healthy.join().unwrap();
    ghost.join().unwrap();
}

/// Compression service: concurrent clients, mixed sizes (exact + hist
/// routes), valid unbiased compressions, consistent metrics.
#[test]
fn compression_service_concurrent_clients() {
    let service = Service::start(ServiceConfig {
        threads: 3,
        queue_capacity: 64,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        router: Router::new(RouterConfig { exact_max_d: 4096, hist_m: 256, seed: 9 }),
        ..Default::default()
    })
    .unwrap();
    let addr = service.addr().to_string();

    let mut joins = vec![];
    for c in 0..8u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            // Alternate small (exact) and large (hist) requests.
            let d = if c % 2 == 0 { 1024 } else { 20_000 };
            let data: Vec<f32> = (0..d)
                .map(|i| ((i as f32 * 0.01 + c as f32).sin() * 2.0).exp())
                .collect();
            let reply = compress_remote(&addr, c, 16, &data).expect("rpc");
            match reply {
                Msg::CompressReply { request_id, compressed, solver, .. } => {
                    assert_eq!(request_id, c);
                    assert_eq!(compressed.d as usize, d);
                    if d <= 4096 {
                        assert_eq!(solver, "quiver-accel");
                    } else {
                        assert_eq!(solver, "quiver-hist(M=256)");
                    }
                    // Decode: all estimates within the data range.
                    let back = sq::decompress(&compressed);
                    let (lo, hi) = data.iter().fold(
                        (f32::INFINITY, f32::NEG_INFINITY),
                        |(l, h), &x| (l.min(x), h.max(x)),
                    );
                    for v in back {
                        assert!(v >= lo as f64 - 1e-5 && v <= hi as f64 + 1e-5);
                    }
                }
                other => panic!("expected reply, got {other:?}"),
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    std::thread::sleep(Duration::from_millis(200));
    let m = &service.metrics;
    let accepted = m.accepted.load(std::sync::atomic::Ordering::Relaxed);
    let completed = m.completed.load(std::sync::atomic::Ordering::Relaxed);
    let rejected = m.rejected.load(std::sync::atomic::Ordering::Relaxed);
    eprintln!("DBG accepted={accepted} completed={completed} rejected={rejected}");
    assert_eq!(accepted, 8);
    assert_eq!(completed, 8);
    assert!(m.ratio() > 4.0, "compression ratio {}", m.ratio());
    service.shutdown();
}

/// Batcher under contention with the parallel workers enabled: a small
/// queue, a 4-thread solver pool whose jobs fan out onto the `par`
/// executor, and 16 bursty clients. Every request must resolve to exactly
/// one of {reply, busy}, the metrics must balance, and replies must be
/// valid compressions — no losses, dupes, deadlocks, or panics from the
/// nested (pool × executor) parallelism.
#[test]
fn batcher_contention_with_parallel_workers() {
    /// Restores the executor width even if an assertion below panics, so
    /// a failure here can't leak a pinned width into later tests.
    struct WidthGuard(usize);
    impl Drop for WidthGuard {
        fn drop(&mut self) {
            quiver::par::set_threads(self.0);
        }
    }
    let _guard = WidthGuard(quiver::par::threads());
    // Force real data-parallel fan-out per job (never lower the width —
    // concurrent tests in this binary only ever see it raised).
    quiver::par::set_threads(quiver::par::threads().max(4));
    let service = Service::start(ServiceConfig {
        threads: 4,
        queue_capacity: 8,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        router: Router::new(RouterConfig { exact_max_d: 1 << 12, hist_m: 256, seed: 5 }),
        ..Default::default()
    })
    .unwrap();
    let addr = service.addr().to_string();

    let clients = 16u64;
    let per_client = 4u64;
    let mut joins = vec![];
    for c in 0..clients {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut ok = 0u64;
            let mut busy = 0u64;
            for i in 0..per_client {
                // Mixed sizes: exact route (small) and hist route (large,
                // chunk-crossing so the executor genuinely splits it).
                let d = if (c + i) % 2 == 0 { 2048 } else { 100_000 };
                let data: Vec<f32> =
                    (0..d).map(|k| ((k as f32 * 0.003 + c as f32).sin() * 1.5).exp()).collect();
                match compress_remote(&addr, c * 100 + i, 16, &data).expect("rpc") {
                    Msg::CompressReply { request_id, compressed, .. } => {
                        assert_eq!(request_id, c * 100 + i);
                        assert_eq!(compressed.d as usize, d);
                        assert_eq!(sq::decompress(&compressed).len(), d);
                        ok += 1;
                    }
                    Msg::Busy { request_id } => {
                        assert_eq!(request_id, c * 100 + i);
                        busy += 1;
                    }
                    other => panic!("unexpected {other:?}"),
                }
            }
            (ok, busy)
        }));
    }
    let (mut ok, mut busy) = (0u64, 0u64);
    for j in joins {
        let (o, b) = j.join().unwrap();
        ok += o;
        busy += b;
    }
    assert_eq!(ok + busy, clients * per_client, "every request resolved exactly once");
    assert!(ok > 0, "contention must not starve the pool entirely");
    // Let in-flight completion counters settle, then balance the books.
    std::thread::sleep(Duration::from_millis(200));
    let m = &service.metrics;
    let accepted = m.accepted.load(std::sync::atomic::Ordering::Relaxed);
    let rejected = m.rejected.load(std::sync::atomic::Ordering::Relaxed);
    let completed = m.completed.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(accepted, ok, "accepted == client-observed replies");
    assert_eq!(rejected, busy, "rejected == client-observed busy");
    assert_eq!(completed, ok, "all accepted jobs completed");
    service.shutdown();
}

/// Backpressure: a single slow solver thread and a depth-1 queue must turn
/// excess load into `Busy` replies, never into unbounded queueing.
#[test]
fn compression_service_backpressure() {
    let service = Service::start(ServiceConfig {
        threads: 1,
        queue_capacity: 1,
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        // Exact route for large-ish vectors = deliberately slow.
        router: Router::new(RouterConfig { exact_max_d: 1 << 22, hist_m: 256, seed: 9 }),
        ..Default::default()
    })
    .unwrap();
    let addr = service.addr().to_string();

    let n = 12u64;
    let mut joins = vec![];
    for c in 0..n {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let data: Vec<f32> = (0..60_000).map(|i| (i as f32 * 0.001).sin()).collect();
            match compress_remote(&addr, c, 8, &data).expect("rpc") {
                Msg::CompressReply { .. } => 0u64,
                Msg::Busy { request_id } => {
                    assert_eq!(request_id, c);
                    1u64
                }
                other => panic!("unexpected {other:?}"),
            }
        }));
    }
    let rejected: u64 = joins.into_iter().map(|j| j.join().unwrap()).sum();
    let m = &service.metrics;
    let acc = m.accepted.load(std::sync::atomic::Ordering::Relaxed);
    let rej = m.rejected.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(acc + rej, n, "every request is either accepted or rejected");
    assert_eq!(rej, rejected);
    assert!(rej > 0, "flooding a depth-1 queue must shed load");
    service.shutdown();
}
