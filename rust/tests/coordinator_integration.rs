//! Loopback integration tests for the L3 coordinator: a real TCP
//! parameter-server round-trip with AVQ-compressed gradients, and the
//! compression microservice under concurrent load.

use std::time::Duration;

use quiver::coordinator::protocol::Msg;
use quiver::coordinator::router::{Router, RouterConfig};
use quiver::coordinator::server::{Server, ServerConfig};
use quiver::coordinator::service::{
    compress_remote, compress_remote_with, Service, ServiceConfig,
};
use quiver::coordinator::shard::{ShardConfig, ShardCoordinator, ShardNode};
use quiver::coordinator::tasks::QuadraticToy;
use quiver::coordinator::worker::{run_worker, WorkerConfig};
use quiver::sq;

/// Federated training over loopback TCP: 4 workers on a convex toy task.
/// The loss must collapse and the uplink must be ~8× smaller than raw.
#[test]
fn federated_round_trip_converges() {
    let dim = 400;
    let workers = 4;
    let rounds = 40;
    let target: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.05).sin() * 3.0).collect();

    let server = Server::bind(ServerConfig {
        workers,
        rounds,
        dim,
        lr: 0.3,
        round_timeout: Duration::from_secs(20),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr().unwrap();

    let mut joins = vec![];
    for w in 0..workers {
        let addr = addr.clone();
        let target = target.clone();
        joins.push(std::thread::spawn(move || {
            let cfg = WorkerConfig {
                id: w as u64,
                s: 16,
                router: Router::default(),
                seed: 1000 + w as u64,
            };
            let toy = QuadraticToy::new(target, 0.01, 2000 + w as u64);
            run_worker(&addr, cfg, toy).expect("worker")
        }));
    }

    let (final_params, log) = server.run(vec![0f32; dim]).expect("server run");
    let stats: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();

    // Convergence: the quadratic's loss collapses by orders of magnitude.
    let first = log.rounds.first().unwrap().mean_loss;
    let last = log.rounds.last().unwrap().mean_loss;
    assert!(
        last < first * 0.01,
        "loss should collapse: {first} -> {last}"
    );
    for (p, t) in final_params.iter().zip(&target) {
        assert!((p - t).abs() < 0.1, "{p} vs {t}");
    }
    // Compression accounting: 4-bit codes ≈ 8× smaller than f32.
    let (compressed, raw) = log.totals();
    assert!(
        raw > 0 && compressed * 4 < raw,
        "ratio {}x",
        raw as f64 / compressed as f64
    );
    // Every round got all submissions.
    for r in &log.rounds {
        assert_eq!(r.submissions, workers);
    }
    for s in &stats {
        assert_eq!(s.rounds, rounds);
        assert!(s.bytes_sent * 4 < s.bytes_raw);
    }
}

/// A worker that vanishes after admission: the server must fail cleanly
/// (no hang) once sends fail or the round times out.
#[test]
fn server_survives_dead_worker_with_timeout() {
    let dim = 50;
    let server = Server::bind(ServerConfig {
        workers: 2,
        rounds: 5,
        dim,
        lr: 0.1,
        round_timeout: Duration::from_millis(300),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr().unwrap();

    // Worker 0: healthy.
    let a0 = addr.clone();
    let healthy = std::thread::spawn(move || {
        let cfg = WorkerConfig { id: 0, s: 4, router: Router::default(), seed: 1 };
        let toy = QuadraticToy::new(vec![1.0; 50], 0.0, 2);
        // May error when the server aborts early — either way it must return.
        let _ = run_worker(&a0, cfg, toy);
    });
    // Worker 1: says hello, then disappears.
    let a1 = addr.clone();
    let ghost = std::thread::spawn(move || {
        use quiver::coordinator::protocol::{recv, send};
        let mut s = std::net::TcpStream::connect(&a1).unwrap();
        send(&mut s, &Msg::Hello { worker_id: 1 }).unwrap();
        let mut rd = std::io::BufReader::new(s.try_clone().unwrap());
        let _ = recv(&mut rd); // Welcome
        drop(s); // vanish
    });

    let started = std::time::Instant::now();
    // With one healthy worker the server still makes progress (aggregates
    // the submissions it has) or errors cleanly — it must not hang.
    let result = server.run(vec![0f32; dim]);
    assert!(started.elapsed() < Duration::from_secs(10), "server hung");
    match result {
        Ok((_, log)) => {
            assert!(!log.rounds.is_empty());
            for r in &log.rounds {
                assert!(r.submissions >= 1);
            }
        }
        Err(e) => {
            // Acceptable: broken pipe to the ghost. Must be an error, not a hang.
            eprintln!("server errored as expected: {e:#}");
        }
    }
    healthy.join().unwrap();
    ghost.join().unwrap();
}

/// Compression service: concurrent clients, mixed sizes (exact + hist
/// routes), valid unbiased compressions, consistent metrics.
#[test]
fn compression_service_concurrent_clients() {
    let service = Service::start(ServiceConfig {
        threads: 3,
        queue_capacity: 64,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        router: Router::new(RouterConfig { exact_max_d: 4096, hist_m: 256, seed: 9, shards: 1 }),
        ..Default::default()
    })
    .unwrap();
    let addr = service.addr().to_string();

    let mut joins = vec![];
    for c in 0..8u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            // Alternate small (exact) and large (hist) requests.
            let d = if c % 2 == 0 { 1024 } else { 20_000 };
            let data: Vec<f32> = (0..d)
                .map(|i| ((i as f32 * 0.01 + c as f32).sin() * 2.0).exp())
                .collect();
            let reply = compress_remote(&addr, c, 16, &data).expect("rpc");
            match reply {
                Msg::CompressReply { request_id, compressed, solver, .. } => {
                    assert_eq!(request_id, c);
                    assert_eq!(compressed.d as usize, d);
                    if d <= 4096 {
                        assert_eq!(solver, "quiver-accel");
                    } else {
                        assert_eq!(solver, "quiver-hist(M=256)");
                    }
                    // Decode: all estimates within the data range.
                    let back = sq::decompress(&compressed);
                    let (lo, hi) = data.iter().fold(
                        (f32::INFINITY, f32::NEG_INFINITY),
                        |(l, h), &x| (l.min(x), h.max(x)),
                    );
                    for v in back {
                        assert!(v >= lo as f64 - 1e-5 && v <= hi as f64 + 1e-5);
                    }
                }
                other => panic!("expected reply, got {other:?}"),
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    std::thread::sleep(Duration::from_millis(200));
    let m = &service.metrics;
    let accepted = m.accepted.load(std::sync::atomic::Ordering::Relaxed);
    let completed = m.completed.load(std::sync::atomic::Ordering::Relaxed);
    let rejected = m.rejected.load(std::sync::atomic::Ordering::Relaxed);
    eprintln!("DBG accepted={accepted} completed={completed} rejected={rejected}");
    assert_eq!(accepted, 8);
    assert_eq!(completed, 8);
    assert!(m.ratio() > 4.0, "compression ratio {}", m.ratio());
    service.shutdown();
}

/// Batcher under contention with the parallel workers enabled: a small
/// queue, a 4-thread solver pool whose jobs fan out onto the `par`
/// executor, and 16 bursty clients. Every request must resolve to exactly
/// one of {reply, busy}, the metrics must balance, and replies must be
/// valid compressions — no losses, dupes, deadlocks, or panics from the
/// nested (pool × executor) parallelism.
#[test]
fn batcher_contention_with_parallel_workers() {
    /// Restores the executor width even if an assertion below panics, so
    /// a failure here can't leak a pinned width into later tests.
    struct WidthGuard(usize);
    impl Drop for WidthGuard {
        fn drop(&mut self) {
            quiver::par::set_threads(self.0);
        }
    }
    let _guard = WidthGuard(quiver::par::threads());
    // Force real data-parallel fan-out per job (never lower the width —
    // concurrent tests in this binary only ever see it raised).
    quiver::par::set_threads(quiver::par::threads().max(4));
    let service = Service::start(ServiceConfig {
        threads: 4,
        queue_capacity: 8,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        router: Router::new(RouterConfig { exact_max_d: 1 << 12, hist_m: 256, seed: 5, shards: 1 }),
        ..Default::default()
    })
    .unwrap();
    let addr = service.addr().to_string();

    let clients = 16u64;
    let per_client = 4u64;
    let mut joins = vec![];
    for c in 0..clients {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut ok = 0u64;
            let mut busy = 0u64;
            for i in 0..per_client {
                // Mixed sizes: exact route (small) and hist route (large,
                // chunk-crossing so the executor genuinely splits it).
                let d = if (c + i) % 2 == 0 { 2048 } else { 100_000 };
                let data: Vec<f32> =
                    (0..d).map(|k| ((k as f32 * 0.003 + c as f32).sin() * 1.5).exp()).collect();
                match compress_remote(&addr, c * 100 + i, 16, &data).expect("rpc") {
                    Msg::CompressReply { request_id, compressed, .. } => {
                        assert_eq!(request_id, c * 100 + i);
                        assert_eq!(compressed.d as usize, d);
                        assert_eq!(sq::decompress(&compressed).len(), d);
                        ok += 1;
                    }
                    Msg::Busy { request_id } => {
                        assert_eq!(request_id, c * 100 + i);
                        busy += 1;
                    }
                    other => panic!("unexpected {other:?}"),
                }
            }
            (ok, busy)
        }));
    }
    let (mut ok, mut busy) = (0u64, 0u64);
    for j in joins {
        let (o, b) = j.join().unwrap();
        ok += o;
        busy += b;
    }
    assert_eq!(ok + busy, clients * per_client, "every request resolved exactly once");
    assert!(ok > 0, "contention must not starve the pool entirely");
    // Let in-flight completion counters settle, then balance the books.
    std::thread::sleep(Duration::from_millis(200));
    let m = &service.metrics;
    let accepted = m.accepted.load(std::sync::atomic::Ordering::Relaxed);
    let rejected = m.rejected.load(std::sync::atomic::Ordering::Relaxed);
    let completed = m.completed.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(accepted, ok, "accepted == client-observed replies");
    assert_eq!(rejected, busy, "rejected == client-observed busy");
    assert_eq!(completed, ok, "all accepted jobs completed");
    service.shutdown();
}

/// Real TCP shard nodes on loopback: a vector split across three nodes
/// must produce the bit-identical `(Solution, CompressedVec)` of the
/// in-process sharded path *and* of the single-node solve — the shard
/// layer's contract, over an actual wire.
#[test]
fn remote_shard_nodes_match_local_and_single_node() {
    use quiver::avq::histogram::{solve_hist, HistConfig};
    use quiver::dist::Dist;
    use quiver::util::rng::Xoshiro256pp;

    let nodes: Vec<ShardNode> =
        (0..3).map(|_| ShardNode::start("127.0.0.1:0").expect("shard node")).collect();
    let addrs: Vec<String> = nodes.iter().map(|n| n.addr().to_string()).collect();

    let d = 2 * quiver::par::CHUNK + 999;
    let xs = Dist::LogNormal { mu: 0.0, sigma: 1.0 }.sample_vec(d, 0x7EA);
    let coord =
        ShardCoordinator::new(ShardConfig { shards: 3, m: 333, ..Default::default() });

    // Single-node reference (same hist seed as ShardConfig::default).
    let ref_sol = solve_hist(&xs, 16, &HistConfig::fixed(333)).unwrap();
    let mut ref_rng = Xoshiro256pp::seed_from_u64(0xAB);
    let ref_c = sq::compress(&xs, &ref_sol.q, &mut ref_rng);

    // In-process sharded.
    let mut local_rng = Xoshiro256pp::seed_from_u64(0xAB);
    let (local_sol, local_c) = coord.compress(&xs, 16, &mut local_rng).unwrap();

    // Over the wire.
    let mut remote_rng = Xoshiro256pp::seed_from_u64(0xAB);
    let (remote_sol, remote_c) =
        coord.compress_remote(&addrs, &xs, 16, &mut remote_rng).expect("remote solve");

    assert_eq!(local_sol.q_idx, ref_sol.q_idx);
    assert_eq!(remote_sol.q_idx, ref_sol.q_idx);
    assert_eq!(remote_sol.mse.to_bits(), ref_sol.mse.to_bits());
    assert_eq!(local_c, ref_c, "in-process sharded == single node");
    assert_eq!(remote_c, ref_c, "remote sharded == single node");

    // A second task over fresh connections still works (nodes are
    // stateless across tasks apart from per-connection sessions).
    let ys = Dist::Normal { mu: 0.0, sigma: 1.0 }.sample_vec(5000, 0x7EB);
    let ref2 = solve_hist(&ys, 8, &HistConfig::fixed(333)).unwrap();
    let mut rng2 = Xoshiro256pp::seed_from_u64(0xAC);
    let (sol2, _) = coord.compress_remote(&addrs, &ys, 8, &mut rng2).expect("second task");
    assert_eq!(sol2.mse.to_bits(), ref2.mse.to_bits());

    for n in nodes {
        n.shutdown();
    }
}

/// Cross-batch admission + tenant classes under load: every request must
/// resolve exactly once with balanced metrics, and a degenerate-constant
/// mix exercises the packed wave path. (Deterministic packing assertions
/// live in the scheduler unit tests; here we prove the service stays
/// correct with admission > 1.)
#[test]
fn admission_packing_and_tenant_classes_stay_correct() {
    let service = Service::start(ServiceConfig {
        threads: 1, // one solver: queue backs up, admission engages
        queue_capacity: 64,
        max_batch: 2,
        max_wait: Duration::from_millis(1),
        admission: 4,
        router: Router::new(RouterConfig { exact_max_d: 4096, hist_m: 128, seed: 3, shards: 1 }),
        ..Default::default()
    })
    .unwrap();
    let addr = service.addr().to_string();

    let clients = 12u64;
    let mut joins = vec![];
    for c in 0..clients {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let d = 2048usize;
            let data: Vec<f32> =
                (0..d).map(|k| ((k as f32 * 0.01 + c as f32).sin() * 1.2).exp()).collect();
            // Mixed classes and deadlines: scheduling order must never
            // affect correctness, only pull order.
            let class = (c % 4) as u8;
            let deadline_ms = if c % 2 == 0 { 50 } else { 0 };
            match compress_remote_with(&addr, c, 8, class, deadline_ms, &data).expect("rpc") {
                Msg::CompressReply { request_id, compressed, .. } => {
                    assert_eq!(request_id, c);
                    assert_eq!(compressed.d as usize, d);
                    assert_eq!(sq::decompress(&compressed).len(), d);
                    1u64
                }
                Msg::Busy { request_id } => {
                    assert_eq!(request_id, c);
                    0u64
                }
                other => panic!("unexpected {other:?}"),
            }
        }));
    }
    let ok: u64 = joins.into_iter().map(|j| j.join().unwrap()).sum();
    std::thread::sleep(Duration::from_millis(200));
    let m = &service.metrics;
    let accepted = m.accepted.load(std::sync::atomic::Ordering::Relaxed);
    let rejected = m.rejected.load(std::sync::atomic::Ordering::Relaxed);
    let completed = m.completed.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(accepted + rejected, clients);
    assert_eq!(accepted, ok);
    assert_eq!(completed, ok);
    assert!(ok > 0, "load must not be fully shed");
    // `packed` counts waves that coalesced extra batches — can be zero on
    // a fast machine (queue never backed up), so only sanity-bound it.
    assert!(m.packed.load(std::sync::atomic::Ordering::Relaxed) <= clients);
    service.shutdown();
}

/// Backpressure: a single slow solver thread and a depth-1 queue must turn
/// excess load into `Busy` replies, never into unbounded queueing.
#[test]
fn compression_service_backpressure() {
    let service = Service::start(ServiceConfig {
        threads: 1,
        queue_capacity: 1,
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        // Exact route for large-ish vectors = deliberately slow.
        router: Router::new(RouterConfig { exact_max_d: 1 << 22, hist_m: 256, seed: 9, shards: 1 }),
        ..Default::default()
    })
    .unwrap();
    let addr = service.addr().to_string();

    let n = 12u64;
    let mut joins = vec![];
    for c in 0..n {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let data: Vec<f32> = (0..60_000).map(|i| (i as f32 * 0.001).sin()).collect();
            match compress_remote(&addr, c, 8, &data).expect("rpc") {
                Msg::CompressReply { .. } => 0u64,
                Msg::Busy { request_id } => {
                    assert_eq!(request_id, c);
                    1u64
                }
                other => panic!("unexpected {other:?}"),
            }
        }));
    }
    let rejected: u64 = joins.into_iter().map(|j| j.join().unwrap()).sum();
    let m = &service.metrics;
    let acc = m.accepted.load(std::sync::atomic::Ordering::Relaxed);
    let rej = m.rejected.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(acc + rej, n, "every request is either accepted or rejected");
    assert_eq!(rej, rejected);
    assert!(rej > 0, "flooding a depth-1 queue must shed load");
    service.shutdown();
}
