//! Loopback integration tests for the L3 coordinator: a real TCP
//! parameter-server round-trip with AVQ-compressed gradients, and the
//! compression microservice under concurrent load.

use std::time::Duration;

use quiver::coordinator::fault::FleetConfig;
use quiver::coordinator::protocol::Msg;
use quiver::coordinator::router::{Router, RouterConfig};
use quiver::coordinator::server::{Server, ServerConfig};
use quiver::coordinator::service::{
    compress_remote, compress_remote_stream, compress_remote_with, Service, ServiceConfig,
    StreamServiceConfig,
};
use quiver::coordinator::shard::{ShardConfig, ShardCoordinator, ShardNode};
use quiver::coordinator::tasks::QuadraticToy;
use quiver::coordinator::worker::{run_worker, WorkerConfig, WorkerStats};
use quiver::sq;
use quiver::stream::{Decision, StreamTuning};

/// Federated training over loopback TCP: 4 workers on a convex toy task.
/// The loss must collapse and the uplink must be ~8× smaller than raw.
#[test]
fn federated_round_trip_converges() {
    let dim = 400;
    let workers = 4;
    let rounds = 40;
    let target: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.05).sin() * 3.0).collect();

    let server = Server::bind(ServerConfig {
        workers,
        rounds,
        dim,
        lr: 0.3,
        round_timeout: Duration::from_secs(20),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr().unwrap();

    let mut joins = vec![];
    for w in 0..workers {
        let addr = addr.clone();
        let target = target.clone();
        joins.push(std::thread::spawn(move || {
            let cfg = WorkerConfig {
                id: w as u64,
                s: 16,
                router: Router::default(),
                seed: 1000 + w as u64,
                stream: None,
                net: FleetConfig::default(),
            };
            let toy = QuadraticToy::new(target, 0.01, 2000 + w as u64);
            run_worker(&addr, cfg, toy).expect("worker")
        }));
    }

    let (final_params, log) = server.run(vec![0f32; dim]).expect("server run");
    let stats: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();

    // Convergence: the quadratic's loss collapses by orders of magnitude.
    let first = log.rounds.first().unwrap().mean_loss;
    let last = log.rounds.last().unwrap().mean_loss;
    assert!(
        last < first * 0.01,
        "loss should collapse: {first} -> {last}"
    );
    for (p, t) in final_params.iter().zip(&target) {
        assert!((p - t).abs() < 0.1, "{p} vs {t}");
    }
    // Compression accounting: 4-bit codes ≈ 8× smaller than f32.
    let (compressed, raw) = log.totals();
    assert!(
        raw > 0 && compressed * 4 < raw,
        "ratio {}x",
        raw as f64 / compressed as f64
    );
    // Every round got all submissions.
    for r in &log.rounds {
        assert_eq!(r.submissions, workers);
    }
    for s in &stats {
        assert_eq!(s.rounds, rounds);
        assert!(s.bytes_sent * 4 < s.bytes_raw);
    }
}

/// A worker that vanishes after admission: the server must fail cleanly
/// (no hang) once sends fail or the round times out.
#[test]
fn server_survives_dead_worker_with_timeout() {
    let dim = 50;
    let server = Server::bind(ServerConfig {
        workers: 2,
        rounds: 5,
        dim,
        lr: 0.1,
        round_timeout: Duration::from_millis(300),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr().unwrap();

    // Worker 0: healthy.
    let a0 = addr.clone();
    let healthy = std::thread::spawn(move || {
        let cfg = WorkerConfig {
            id: 0,
            s: 4,
            router: Router::default(),
            seed: 1,
            stream: None,
            net: FleetConfig::default(),
        };
        let toy = QuadraticToy::new(vec![1.0; 50], 0.0, 2);
        // May error when the server aborts early — either way it must return.
        let _ = run_worker(&a0, cfg, toy);
    });
    // Worker 1: says hello, then disappears.
    let a1 = addr.clone();
    let ghost = std::thread::spawn(move || {
        use quiver::coordinator::protocol::{recv, send};
        let mut s = std::net::TcpStream::connect(&a1).unwrap();
        send(&mut s, &Msg::Hello { worker_id: 1 }).unwrap();
        let mut rd = std::io::BufReader::new(s.try_clone().unwrap());
        let _ = recv(&mut rd); // Welcome
        drop(s); // vanish
    });

    let started = std::time::Instant::now();
    // With one healthy worker the server still makes progress (aggregates
    // the submissions it has) or errors cleanly — it must not hang.
    let result = server.run(vec![0f32; dim]);
    assert!(started.elapsed() < Duration::from_secs(10), "server hung");
    match result {
        Ok((_, log)) => {
            assert!(!log.rounds.is_empty());
            for r in &log.rounds {
                assert!(r.submissions >= 1);
            }
        }
        Err(e) => {
            // Acceptable: broken pipe to the ghost. Must be an error, not a hang.
            eprintln!("server errored as expected: {e:#}");
        }
    }
    healthy.join().unwrap();
    ghost.join().unwrap();
}

/// Compression service: concurrent clients, mixed sizes (exact + hist
/// routes), valid unbiased compressions, consistent metrics.
#[test]
fn compression_service_concurrent_clients() {
    let service = Service::start(ServiceConfig {
        threads: 3,
        queue_capacity: 64,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        router: Router::new(RouterConfig { exact_max_d: 4096, hist_m: 256, seed: 9, shards: 1 }),
        ..Default::default()
    })
    .unwrap();
    let addr = service.addr().to_string();

    let mut joins = vec![];
    for c in 0..8u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            // Alternate small (exact) and large (hist) requests.
            let d = if c % 2 == 0 { 1024 } else { 20_000 };
            let data: Vec<f32> = (0..d)
                .map(|i| ((i as f32 * 0.01 + c as f32).sin() * 2.0).exp())
                .collect();
            let reply = compress_remote(&addr, c, 16, &data).expect("rpc");
            match reply {
                Msg::CompressReply { request_id, compressed, solver, .. } => {
                    assert_eq!(request_id, c);
                    assert_eq!(compressed.d as usize, d);
                    if d <= 4096 {
                        assert_eq!(solver, "quiver-accel");
                    } else {
                        assert_eq!(solver, "quiver-hist(M=256)");
                    }
                    // Decode: all estimates within the data range.
                    let back = sq::decompress(&compressed);
                    let (lo, hi) = data.iter().fold(
                        (f32::INFINITY, f32::NEG_INFINITY),
                        |(l, h), &x| (l.min(x), h.max(x)),
                    );
                    for v in back {
                        assert!(v >= lo as f64 - 1e-5 && v <= hi as f64 + 1e-5);
                    }
                }
                other => panic!("expected reply, got {other:?}"),
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    std::thread::sleep(Duration::from_millis(200));
    let m = &service.metrics;
    let accepted = m.accepted.load(std::sync::atomic::Ordering::Relaxed);
    let completed = m.completed.load(std::sync::atomic::Ordering::Relaxed);
    let rejected = m.rejected.load(std::sync::atomic::Ordering::Relaxed);
    eprintln!("DBG accepted={accepted} completed={completed} rejected={rejected}");
    assert_eq!(accepted, 8);
    assert_eq!(completed, 8);
    assert!(m.ratio() > 4.0, "compression ratio {}", m.ratio());
    service.shutdown();
}

/// Batcher under contention with the parallel workers enabled: a small
/// queue, a 4-thread solver pool whose jobs fan out onto the `par`
/// executor, and 16 bursty clients. Every request must resolve to exactly
/// one of {reply, busy}, the metrics must balance, and replies must be
/// valid compressions — no losses, dupes, deadlocks, or panics from the
/// nested (pool × executor) parallelism.
#[test]
fn batcher_contention_with_parallel_workers() {
    /// Restores the executor width even if an assertion below panics, so
    /// a failure here can't leak a pinned width into later tests.
    struct WidthGuard(usize);
    impl Drop for WidthGuard {
        fn drop(&mut self) {
            quiver::par::set_threads(self.0);
        }
    }
    let _guard = WidthGuard(quiver::par::threads());
    // Force real data-parallel fan-out per job (never lower the width —
    // concurrent tests in this binary only ever see it raised).
    quiver::par::set_threads(quiver::par::threads().max(4));
    let service = Service::start(ServiceConfig {
        threads: 4,
        queue_capacity: 8,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        router: Router::new(RouterConfig { exact_max_d: 1 << 12, hist_m: 256, seed: 5, shards: 1 }),
        ..Default::default()
    })
    .unwrap();
    let addr = service.addr().to_string();

    let clients = 16u64;
    let per_client = 4u64;
    let mut joins = vec![];
    for c in 0..clients {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut ok = 0u64;
            let mut busy = 0u64;
            for i in 0..per_client {
                // Mixed sizes: exact route (small) and hist route (large,
                // chunk-crossing so the executor genuinely splits it).
                let d = if (c + i) % 2 == 0 { 2048 } else { 100_000 };
                let data: Vec<f32> =
                    (0..d).map(|k| ((k as f32 * 0.003 + c as f32).sin() * 1.5).exp()).collect();
                match compress_remote(&addr, c * 100 + i, 16, &data).expect("rpc") {
                    Msg::CompressReply { request_id, compressed, .. } => {
                        assert_eq!(request_id, c * 100 + i);
                        assert_eq!(compressed.d as usize, d);
                        assert_eq!(sq::decompress(&compressed).len(), d);
                        ok += 1;
                    }
                    Msg::Busy { request_id } => {
                        assert_eq!(request_id, c * 100 + i);
                        busy += 1;
                    }
                    other => panic!("unexpected {other:?}"),
                }
            }
            (ok, busy)
        }));
    }
    let (mut ok, mut busy) = (0u64, 0u64);
    for j in joins {
        let (o, b) = j.join().unwrap();
        ok += o;
        busy += b;
    }
    assert_eq!(ok + busy, clients * per_client, "every request resolved exactly once");
    assert!(ok > 0, "contention must not starve the pool entirely");
    // Let in-flight completion counters settle, then balance the books.
    std::thread::sleep(Duration::from_millis(200));
    let m = &service.metrics;
    let accepted = m.accepted.load(std::sync::atomic::Ordering::Relaxed);
    let rejected = m.rejected.load(std::sync::atomic::Ordering::Relaxed);
    let completed = m.completed.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(accepted, ok, "accepted == client-observed replies");
    assert_eq!(rejected, busy, "rejected == client-observed busy");
    assert_eq!(completed, ok, "all accepted jobs completed");
    service.shutdown();
}

/// Real TCP shard nodes on loopback: a vector split across three nodes
/// must produce the bit-identical `(Solution, CompressedVec)` of the
/// in-process sharded path *and* of the single-node solve — the shard
/// layer's contract, over an actual wire.
#[test]
fn remote_shard_nodes_match_local_and_single_node() {
    use quiver::avq::histogram::{solve_hist, HistConfig};
    use quiver::dist::Dist;
    use quiver::util::rng::Xoshiro256pp;

    let nodes: Vec<ShardNode> =
        (0..3).map(|_| ShardNode::start("127.0.0.1:0").expect("shard node")).collect();
    let addrs: Vec<String> = nodes.iter().map(|n| n.addr().to_string()).collect();

    let d = 2 * quiver::par::CHUNK + 999;
    let xs = Dist::LogNormal { mu: 0.0, sigma: 1.0 }.sample_vec(d, 0x7EA);
    let coord =
        ShardCoordinator::new(ShardConfig { shards: 3, m: 333, ..Default::default() });

    // Single-node reference (same hist seed as ShardConfig::default).
    let ref_sol = solve_hist(&xs, 16, &HistConfig::fixed(333)).unwrap();
    let mut ref_rng = Xoshiro256pp::seed_from_u64(0xAB);
    let ref_c = sq::compress(&xs, &ref_sol.q, &mut ref_rng);

    // In-process sharded.
    let mut local_rng = Xoshiro256pp::seed_from_u64(0xAB);
    let (local_sol, local_c) = coord.compress(&xs, 16, &mut local_rng).unwrap();

    // Over the wire.
    let mut remote_rng = Xoshiro256pp::seed_from_u64(0xAB);
    let (remote_sol, remote_c) =
        coord.compress_remote(&addrs, &xs, 16, &mut remote_rng).expect("remote solve");

    assert_eq!(local_sol.q_idx, ref_sol.q_idx);
    assert_eq!(remote_sol.q_idx, ref_sol.q_idx);
    assert_eq!(remote_sol.mse.to_bits(), ref_sol.mse.to_bits());
    assert_eq!(local_c, ref_c, "in-process sharded == single node");
    assert_eq!(remote_c, ref_c, "remote sharded == single node");

    // A second task over fresh connections still works (nodes are
    // stateless across tasks apart from per-connection sessions).
    let ys = Dist::Normal { mu: 0.0, sigma: 1.0 }.sample_vec(5000, 0x7EB);
    let ref2 = solve_hist(&ys, 8, &HistConfig::fixed(333)).unwrap();
    let mut rng2 = Xoshiro256pp::seed_from_u64(0xAC);
    let (sol2, _) = coord.compress_remote(&addrs, &ys, 8, &mut rng2).expect("second task");
    assert_eq!(sol2.mse.to_bits(), ref2.mse.to_bits());

    for n in nodes {
        n.shutdown();
    }
}

/// Cross-batch admission + tenant classes under load: every request must
/// resolve exactly once with balanced metrics, and a degenerate-constant
/// mix exercises the packed wave path. (Deterministic packing assertions
/// live in the scheduler unit tests; here we prove the service stays
/// correct with admission > 1.)
#[test]
fn admission_packing_and_tenant_classes_stay_correct() {
    let service = Service::start(ServiceConfig {
        threads: 1, // one solver: queue backs up, admission engages
        queue_capacity: 64,
        max_batch: 2,
        max_wait: Duration::from_millis(1),
        admission: 4,
        router: Router::new(RouterConfig { exact_max_d: 4096, hist_m: 128, seed: 3, shards: 1 }),
        ..Default::default()
    })
    .unwrap();
    let addr = service.addr().to_string();

    let clients = 12u64;
    let mut joins = vec![];
    for c in 0..clients {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let d = 2048usize;
            let data: Vec<f32> =
                (0..d).map(|k| ((k as f32 * 0.01 + c as f32).sin() * 1.2).exp()).collect();
            // Mixed classes and deadlines: scheduling order must never
            // affect correctness, only pull order.
            let class = (c % 4) as u8;
            let deadline_ms = if c % 2 == 0 { 50 } else { 0 };
            match compress_remote_with(&addr, c, 8, class, deadline_ms, &data).expect("rpc") {
                Msg::CompressReply { request_id, compressed, .. } => {
                    assert_eq!(request_id, c);
                    assert_eq!(compressed.d as usize, d);
                    assert_eq!(sq::decompress(&compressed).len(), d);
                    1u64
                }
                Msg::Busy { request_id } => {
                    assert_eq!(request_id, c);
                    0u64
                }
                other => panic!("unexpected {other:?}"),
            }
        }));
    }
    let ok: u64 = joins.into_iter().map(|j| j.join().unwrap()).sum();
    std::thread::sleep(Duration::from_millis(200));
    let m = &service.metrics;
    let accepted = m.accepted.load(std::sync::atomic::Ordering::Relaxed);
    let rejected = m.rejected.load(std::sync::atomic::Ordering::Relaxed);
    let completed = m.completed.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(accepted + rejected, clients);
    assert_eq!(accepted, ok);
    assert_eq!(completed, ok);
    assert!(ok > 0, "load must not be fully shed");
    // `packed` counts waves that coalesced extra batches — can be zero on
    // a fast machine (queue never backed up), so only sanity-bound it.
    assert!(m.packed.load(std::sync::atomic::Ordering::Relaxed) <= clients);
    service.shutdown();
}

/// One full loopback training run; returns the final parameters, the
/// per-round uplink byte counts, and the worker stats. With two workers
/// the aggregation is a commutative two-term sum, so the whole run is
/// bitwise-deterministic regardless of submission arrival order — which
/// lets the sharded-vs-unsharded and streaming comparisons below assert
/// bit equality end to end.
fn run_train(shards: usize, stream: bool) -> (Vec<f32>, Vec<usize>, Vec<WorkerStats>) {
    let dim = 5000;
    let workers = 2;
    let rounds = 8;
    let target: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.01).sin() * 2.0).collect();
    let server = Server::bind(ServerConfig {
        workers,
        rounds,
        dim,
        lr: 0.3,
        round_timeout: Duration::from_secs(20),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr().unwrap();
    let mut joins = vec![];
    for w in 0..workers {
        let addr = addr.clone();
        let target = target.clone();
        joins.push(std::thread::spawn(move || {
            let cfg = WorkerConfig {
                id: w as u64,
                s: 16,
                // Gradients (d = 5000) exceed the crossover, so the
                // histogram route — the one sharding applies to — serves
                // every round.
                router: Router::new(RouterConfig {
                    exact_max_d: 64,
                    hist_m: 128,
                    seed: 5,
                    shards,
                }),
                seed: 1000 + w as u64,
                stream: stream.then(|| StreamTuning {
                    drift_warm_max: 10.0, // converging gradients drift hard
                    ..StreamTuning::default()
                }),
                net: FleetConfig::default(),
            };
            let toy = QuadraticToy::new(target, 0.0, 2000 + w as u64);
            run_worker(&addr, cfg, toy).expect("worker")
        }));
    }
    let (final_params, log) = server.run(vec![0f32; dim]).expect("server run");
    let stats: Vec<WorkerStats> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let bytes: Vec<usize> = log.rounds.iter().map(|r| r.bytes_up).collect();
    // Sanity on every variant: training converges.
    let first = log.rounds.first().unwrap().mean_loss;
    let last = log.rounds.last().unwrap().mean_loss;
    assert!(last < first * 0.2, "loss should drop: {first} -> {last}");
    (final_params, bytes, stats)
}

/// The ROADMAP's sharded federated round path: routing one model's
/// gradient through `RouterConfig::shards` (so a single gradient can span
/// trainer nodes) must be invisible in training — final parameters and
/// every round's uplink bytes bit-equal to the unsharded run. Holds in
/// classic mode and in streaming mode (where the stream solver itself
/// shards its round histograms).
#[test]
fn sharded_federated_rounds_bit_equal_unsharded() {
    let (p1, b1, _) = run_train(1, false);
    let (p2, b2, _) = run_train(2, false);
    assert_eq!(b1, b2, "per-round uplink bytes must not change with sharding");
    let bits = |p: &[f32]| p.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&p1), bits(&p2), "final parameters must be bit-equal");

    let (p3, b3, s3) = run_train(1, true);
    let (p4, b4, _) = run_train(4, true);
    assert_eq!(b3, b4, "streaming: uplink bytes must not change with sharding");
    assert_eq!(bits(&p3), bits(&p4), "streaming: final parameters bit-equal");
    // The streaming workers actually ran the incremental path.
    let m = s3[0].stream.expect("streaming stats recorded");
    assert_eq!(m.rounds, 8);
    assert!(m.resolved >= 1, "round 0 is always a re-solve");
}

/// Streaming service over real TCP: rounds of a stationary stream resolve
/// once then reuse/warm-start; a fresh service instance with the same
/// stream seed reproduces every round's bytes exactly; and a service
/// without streaming configured answers `Busy`.
#[test]
fn streaming_service_rounds_reproducible_over_tcp() {
    let mk = || {
        Service::start(ServiceConfig {
            threads: 2,
            queue_capacity: 64,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            router: Router::new(RouterConfig { exact_max_d: 1024, hist_m: 128, seed: 9, shards: 1 }),
            stream: Some(StreamServiceConfig { seed: 0xFEED, ..Default::default() }),
            ..Default::default()
        })
        .expect("service")
    };
    // Stationary rounds with pinned endpoints (sentinels survive the f32
    // round-trip exactly), so the grid repeats and reuse can engage.
    let round_data = |r: u64| -> Vec<f32> {
        let mut v: Vec<f32> = (0..3000)
            .map(|i| (((i as f32) * 0.37 + r as f32 * 11.0).sin() * 1.7).clamp(-3.9, 3.9))
            .collect();
        v[0] = -4.0;
        v[1] = 4.0;
        v
    };
    let drive = |addr: &str| -> Vec<(u8, Vec<u8>, u64)> {
        (0..4u64)
            .map(|r| {
                match compress_remote_stream(addr, r, 42, r, 8, &round_data(r)).expect("rpc") {
                    Msg::StreamCompressReply { request_id, round, decision, compressed, solver, .. } => {
                        assert_eq!(request_id, r);
                        assert_eq!(round, r);
                        assert_eq!(solver, "quiver-stream(M=128)");
                        assert_eq!(compressed.d, 3000);
                        (decision, compressed.payload, compressed.q.len() as u64)
                    }
                    other => panic!("round {r}: unexpected {other:?}"),
                }
            })
            .collect()
    };
    let s1 = mk();
    let run1 = drive(s1.addr());
    assert_eq!(run1[0].0, Decision::Resolve.code(), "first round must re-solve");
    assert!(
        run1[1..].iter().any(|(d, _, _)| *d != Decision::Resolve.code()),
        "stationary rounds should reuse/warm at least once: {:?}",
        run1.iter().map(|(d, _, _)| *d).collect::<Vec<_>>()
    );
    let m = &s1.metrics;
    let resolved = m.stream_resolved.load(std::sync::atomic::Ordering::Relaxed);
    let non_resolve = m.stream_reused.load(std::sync::atomic::Ordering::Relaxed)
        + m.stream_warm.load(std::sync::atomic::Ordering::Relaxed)
        + m.stream_cached.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(resolved + non_resolve, 4);
    assert!(m.summary().contains("stream="));
    s1.shutdown();

    // A brand-new service instance with the same stream seed replays the
    // same rounds to the same bytes — per-tenant streams are reproducible
    // from (seed, stream_id, round, data) alone.
    let s2 = mk();
    let run2 = drive(s2.addr());
    assert_eq!(run1, run2, "fresh instance must reproduce every round");
    // Plain one-shot traffic coexists with streaming.
    match compress_remote(s2.addr(), 7, 8, &round_data(0)).expect("rpc") {
        Msg::CompressReply { request_id, .. } => assert_eq!(request_id, 7),
        other => panic!("unexpected {other:?}"),
    }
    s2.shutdown();

    // Streaming traffic to a non-streaming service: clean Busy.
    let plain = Service::start(ServiceConfig::default()).expect("service");
    match compress_remote_stream(plain.addr(), 1, 1, 0, 8, &round_data(0)).expect("rpc") {
        Msg::Busy { request_id } => assert_eq!(request_id, 1),
        other => panic!("expected Busy, got {other:?}"),
    }
    plain.shutdown();
}

/// Deadline shedding (`--shed-expired`): a request whose deadline expires
/// while it queues behind a slow solve is answered `Busy` at pop time and
/// counted by the `shed=` metric, instead of burning a solve.
#[test]
fn shed_expired_service_answers_busy_for_late_jobs() {
    let service = Service::start(ServiceConfig {
        threads: 1,
        queue_capacity: 8,
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        shed_expired: true,
        // Exact route for a large vector = a deliberately slow first job.
        router: Router::new(RouterConfig { exact_max_d: 1 << 22, hist_m: 256, seed: 9, shards: 1 }),
        ..Default::default()
    })
    .unwrap();
    let addr = service.addr().to_string();

    // Job A: slow exact solve (1M coordinates on the exact route takes
    // well over the sleep below on any machine) occupying the single
    // solver thread.
    let a_addr = addr.clone();
    let a = std::thread::spawn(move || {
        let data: Vec<f32> = (0..1 << 20).map(|i| (i as f32 * 0.001).sin()).collect();
        compress_remote(&a_addr, 1, 16, &data).expect("rpc A")
    });
    // Give A time to be pulled (pull happens within the 1 ms linger),
    // then queue B with a 1 ms deadline: by the time the solver pops it —
    // after A's solve — it is long expired.
    std::thread::sleep(Duration::from_millis(20));
    let data_b: Vec<f32> = (0..2000).map(|i| (i as f32 * 0.01).cos()).collect();
    let b = compress_remote_with(&addr, 2, 8, 0, 1, &data_b).expect("rpc B");
    match b {
        Msg::Busy { request_id } => assert_eq!(request_id, 2),
        other => panic!("expected shed Busy, got {other:?}"),
    }
    match a.join().unwrap() {
        Msg::CompressReply { request_id, .. } => assert_eq!(request_id, 1),
        other => panic!("unexpected {other:?}"),
    }
    let shed = service.metrics.shed.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(shed, 1, "exactly the expired job was shed");
    service.shutdown();
}

/// Backpressure: a single slow solver thread and a depth-1 queue must turn
/// excess load into `Busy` replies, never into unbounded queueing.
#[test]
fn compression_service_backpressure() {
    let service = Service::start(ServiceConfig {
        threads: 1,
        queue_capacity: 1,
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        // Exact route for large-ish vectors = deliberately slow.
        router: Router::new(RouterConfig { exact_max_d: 1 << 22, hist_m: 256, seed: 9, shards: 1 }),
        ..Default::default()
    })
    .unwrap();
    let addr = service.addr().to_string();

    let n = 12u64;
    let mut joins = vec![];
    for c in 0..n {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let data: Vec<f32> = (0..60_000).map(|i| (i as f32 * 0.001).sin()).collect();
            match compress_remote(&addr, c, 8, &data).expect("rpc") {
                Msg::CompressReply { .. } => 0u64,
                Msg::Busy { request_id } => {
                    assert_eq!(request_id, c);
                    1u64
                }
                other => panic!("unexpected {other:?}"),
            }
        }));
    }
    let rejected: u64 = joins.into_iter().map(|j| j.join().unwrap()).sum();
    let m = &service.metrics;
    let acc = m.accepted.load(std::sync::atomic::Ordering::Relaxed);
    let rej = m.rejected.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(acc + rej, n, "every request is either accepted or rejected");
    assert_eq!(rej, rejected);
    assert!(rej > 0, "flooding a depth-1 queue must shed load");
    service.shutdown();
}
