//! Wire-compatibility regression: the epoll front-end speaks the
//! *identical* framed protocol, so every existing blocking client helper
//! (`compress_remote_retry`, `compress_remote_stream`, `ingest_remote`,
//! the `quiver client` CLI built on them) runs unmodified against it —
//! and gets bit-identical reply payloads vs the threaded front-end.
//!
//! Each test stands up one service per front-end with identical seeds
//! and compares the deterministic reply fields (compressed bytes, solver
//! label); `solve_us` is wall time and is the only field allowed to
//! differ. Linux-only like the event loop itself.
#![cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]

use quiver::coordinator::fault::FleetConfig;
use quiver::coordinator::protocol::Msg;
use quiver::coordinator::router::{Router, RouterConfig};
use quiver::coordinator::service::{
    compress_remote_retry, compress_remote_stream, ingest_remote, stats_remote, Frontend, Service,
    ServiceConfig, StreamServiceConfig,
};
use quiver::dist::Dist;
use quiver::stream::StreamTuning;

fn start(frontend: Frontend) -> Service {
    Service::start(ServiceConfig {
        threads: 2,
        frontend,
        router: Router::new(RouterConfig { exact_max_d: 2048, hist_m: 128, seed: 7, shards: 1 }),
        stream: Some(StreamServiceConfig {
            tuning: StreamTuning::default(),
            seed: 0x57A3A,
            max_streams: 8,
        }),
        ..Default::default()
    })
    .expect("service")
}

fn sample(d: usize, seed: u64) -> Vec<f32> {
    Dist::LogNormal { mu: 0.0, sigma: 1.0 }
        .sample_vec(d, seed)
        .into_iter()
        .map(|x| x as f32)
        .collect()
}

/// The deterministic part of a compress reply: (compressed, solver).
fn reply_bits(msg: Msg) -> (quiver::sq::CompressedVec, String) {
    match msg {
        Msg::CompressReply { compressed, solver, .. } => (compressed, solver),
        other => panic!("expected CompressReply, got {}", other.kind()),
    }
}

/// One-shot requests through the unmodified blocking retry client: both
/// router paths (exact ≤ 2048, histogram above) must produce the same
/// bytes under either front-end.
#[test]
fn one_shot_replies_bit_identical_across_frontends() {
    let threaded = start(Frontend::Threads);
    let epoll = start(Frontend::Epoll);
    let net = FleetConfig::default();
    for (rid, d) in [(1u64, 100usize), (2, 1000), (3, 3000)] {
        let data = sample(d, 0xC0117 + rid);
        let ra = compress_remote_retry(threaded.addr(), rid, 16, 1, 0, &data, &net).expect("threads");
        let rb = compress_remote_retry(epoll.addr(), rid, 16, 1, 0, &data, &net).expect("epoll");
        let (ca, sa) = reply_bits(ra);
        let (cb, sb) = reply_bits(rb);
        assert_eq!(sa, sb, "solver route must not depend on the front-end (d={d})");
        assert_eq!(ca, cb, "compressed bytes must not depend on the front-end (d={d})");
    }
    threaded.shutdown();
    epoll.shutdown();
}

/// The deterministic part of a streaming reply: everything except
/// `solve_us` (the drift measurement is a pure function of the data, so
/// it must match bit-for-bit too).
fn stream_reply_bits(msg: Msg) -> (quiver::sq::CompressedVec, String, u8, u64) {
    match msg {
        Msg::StreamCompressReply { compressed, solver, decision, drift, .. } => {
            (compressed, solver, decision, drift.to_bits())
        }
        other => panic!("expected StreamCompressReply, got {}", other.kind()),
    }
}

/// Incremental streaming sessions (PR 8's client, unmodified): rounds of
/// one stream id produce byte-identical payloads under either front-end.
#[test]
fn streaming_rounds_bit_identical_across_frontends() {
    let threaded = start(Frontend::Threads);
    let epoll = start(Frontend::Epoll);
    for round in 0..3u64 {
        let data = sample(1500, 0x5EED0 + round);
        let ra =
            compress_remote_stream(threaded.addr(), round, 9, round, 16, &data).expect("threads");
        let rb = compress_remote_stream(epoll.addr(), round, 9, round, 16, &data).expect("epoll");
        assert_eq!(
            stream_reply_bits(ra),
            stream_reply_bits(rb),
            "stream round {round} diverged across front-ends"
        );
    }
    threaded.shutdown();
    epoll.shutdown();
}

/// Chunked ingestion (PR 9's client, unmodified): the multi-frame ingest
/// state machine rides the event loop's partial-read buffers and still
/// produces the monolithic path's exact bytes.
#[test]
fn chunked_ingest_bit_identical_across_frontends() {
    let threaded = start(Frontend::Threads);
    let epoll = start(Frontend::Epoll);
    // Multi-chunk: past one 64K-coordinate chunk boundary.
    let data = sample(70_000, 0x1A57);
    let (ca, sa, _) = ingest_remote(threaded.addr(), 4, 16, 0, 0, &data).expect("threads");
    let (cb, sb, _) = ingest_remote(epoll.addr(), 4, 16, 0, 0, &data).expect("epoll");
    assert_eq!(sa, sb);
    assert_eq!(ca, cb, "ingested bytes diverged across front-ends");
    threaded.shutdown();
    epoll.shutdown();
}

/// Concurrent mixed tenants against the epoll front-end: every reply
/// matches the one the threaded front-end gives for the same request.
#[test]
fn concurrent_mixed_load_bit_identical() {
    let threaded = start(Frontend::Threads);
    let epoll = start(Frontend::Epoll);
    let ta = threaded.addr().to_string();
    let ea = epoll.addr().to_string();
    let mut joins = vec![];
    for t in 0..8u64 {
        let (ta, ea) = (ta.clone(), ea.clone());
        joins.push(std::thread::spawn(move || {
            let net = FleetConfig::default();
            for r in 0..4u64 {
                let rid = t * 100 + r;
                let d = 200 + (rid as usize * 37) % 2600;
                let class = (t % 3) as u8;
                let deadline = if t % 2 == 0 { 0 } else { 10_000 };
                let data = sample(d, 0xABCD ^ rid);
                let ra = compress_remote_retry(&ta, rid, 16, class, deadline, &data, &net)
                    .expect("threads");
                let rb =
                    compress_remote_retry(&ea, rid, 16, class, deadline, &data, &net).expect("epoll");
                assert_eq!(reply_bits(ra), reply_bits(rb), "tenant {t} round {r} diverged");
            }
        }));
    }
    for j in joins {
        j.join().expect("tenant thread");
    }
    threaded.shutdown();
    epoll.shutdown();
}

/// The stats wire message works on both front-ends, and the epoll
/// front-end's connection counters move.
#[test]
fn stats_reply_served_on_both_frontends() {
    let threaded = start(Frontend::Threads);
    let epoll = start(Frontend::Epoll);
    let data = sample(600, 5);
    let net = FleetConfig::default();
    for (svc, label) in [(&threaded, "threads"), (&epoll, "epoll")] {
        let _ = compress_remote_retry(svc.addr(), 11, 16, 0, 0, &data, &net).expect(label);
        let snap = stats_remote(svc.addr(), 99).expect(label);
        assert!(snap.accepted >= 1, "{label}: accepted moved");
        assert!(snap.completed >= 1, "{label}: completed moved");
        assert!(snap.conns_accepted >= 1, "{label}: connection counter moved");
        // One completed request implies non-zero latency quantiles (the
        // histogram's smallest reported bucket edge is 2µs).
        assert!(snap.e2e_p50_us >= 2, "{label}: e2e histogram recorded");
        assert!(snap.e2e_p999_us >= snap.e2e_p50_us, "{label}: quantiles ordered");
    }
    threaded.shutdown();
    epoll.shutdown();
}
