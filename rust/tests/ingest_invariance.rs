//! Gating suite for chunked streaming ingestion (`coordinator::ingest`).
//!
//! The headline contract: a vector ingested one chunk at a time — in ANY
//! arrival order, interleaved with other tenants, on any thread count,
//! backend, or SIMD mode — produces levels and packed payload **bitwise
//! identical** to the monolithic single-buffer pipeline. This is a
//! corollary of DESIGN.md rules 2 and 4 (chunk identity, not arrival
//! order, keys every partial; merges are order-fixed and exact), and this
//! suite is the machine check of that corollary: paper-suite
//! distributions × {forward, reversed, shuffled} arrival × the full
//! execution matrix, plus interleaved multi-tenant arrival through the
//! real per-connection state machine and a live TCP round-trip.
//!
//! The references are computed ONCE at the ambient configuration and
//! compared against every cell, so a pass certifies both arrival-order
//! invariance and cross-configuration invariance in one sweep.

use std::collections::BTreeMap;

use quiver::coordinator::ingest::{self, IngestConfig, IngestConn, IngestEvent};
use quiver::coordinator::router::{Router, RouterConfig};
use quiver::coordinator::service::{ingest_remote, Service, ServiceConfig};
use quiver::dist::Dist;
use quiver::par;
use quiver::sq;
use quiver::testutil::for_each_exec_cell;
use quiver::util::rng::Xoshiro256pp;

/// Quantization budget for every task in this suite.
const S: u32 = 12;
/// Grid intervals — small enough to keep the matrix sweep fast, and the
/// value the TCP service below is configured with (`hist_m`).
const M: usize = 64;

fn cfg() -> IngestConfig {
    IngestConfig { m: M, ..Default::default() }
}

/// Sample a distribution into the f32 wire element type.
fn fsample(dist: &Dist, d: usize, seed: u64) -> Vec<f32> {
    dist.sample_vec(d, seed).into_iter().map(|x| x as f32).collect()
}

#[test]
fn chunked_ingest_is_arrival_order_invariant_across_the_matrix() {
    // Three chunks (two full + ragged tail): enough for 6 distinct
    // arrival permutations, of which we drive forward, reversed, and a
    // seeded shuffle per distribution.
    let d = 2 * par::CHUNK + 777;
    let n_chunks = d.div_ceil(par::CHUNK) as u64;

    // References at the ambient configuration, one per (dist, task id).
    let cases: Vec<_> = Dist::paper_suite()
        .into_iter()
        .enumerate()
        .map(|(i, (name, dist))| {
            let data = fsample(&dist, d, 0xA11 + i as u64);
            let task_id = 10 + i as u64;
            let (want, want_levels) =
                ingest::monolithic_reference(&data, S, &cfg(), task_id).unwrap();
            (name, data, task_id, want, want_levels)
        })
        .collect();

    let forward: Vec<u64> = (0..n_chunks).collect();
    let reversed: Vec<u64> = (0..n_chunks).rev().collect();

    for_each_exec_cell(&[1, 3], |cell| {
        for (i, (name, data, task_id, want, want_levels)) in cases.iter().enumerate() {
            let mut shuffled = forward.clone();
            Xoshiro256pp::seed_from_u64(0xC0FFE + i as u64).shuffle(&mut shuffled);
            for (oname, order) in
                [("forward", &forward), ("reversed", &reversed), ("shuffled", &shuffled)]
            {
                let (got, levels) =
                    ingest::ingest_local(data, S, &cfg(), *task_id, Some(order)).unwrap();
                assert_eq!(
                    &levels, want_levels,
                    "[{cell}] {name}/{oname}: levels must match monolithic"
                );
                assert_eq!(&got, want, "[{cell}] {name}/{oname}: bits must match monolithic");
            }
        }
    });
}

#[test]
fn interleaved_multi_tenant_arrival_matches_monolithic_per_tenant() {
    // Two tenants on ONE connection state machine, their chunks
    // interleaved out of order in both the fill and the echo phase: each
    // tenant's bits must match its own monolithic run exactly, keyed by
    // its task id alone.
    let d = par::CHUNK + 901; // two chunks per tenant
    let suite = Dist::paper_suite();
    let a = fsample(&suite[0].1, d, 51);
    let b = fsample(&suite[1].1, d, 52);
    let want_a = ingest::monolithic_reference(&a, S, &cfg(), 1).unwrap().0;
    let want_b = ingest::monolithic_reference(&b, S, &cfg(), 2).unwrap().0;

    for_each_exec_cell(&[1, 2], |cell| {
        let mut conn = IngestConn::new(cfg());
        for (tid, data) in [(1u64, &a), (2u64, &b)] {
            let (lo, hi) = ingest::declared_range(data);
            let ev = conn.open(tid, d as u64, S, lo, hi);
            assert!(matches!(ev, IngestEvent::Accepted), "[{cell}] open {tid}: {ev:?}");
        }
        // Fill phase: tenants and chunk indices interleaved arbitrarily.
        for (tid, ci, data) in [(2u64, 1u64, &b), (1, 1, &a), (2, 0, &b), (1, 0, &a)] {
            let ev = conn.chunk(tid, ci, ingest::chunk_of(data, ci));
            assert!(matches!(ev, IngestEvent::Folded), "[{cell}] fill {tid}/{ci}: {ev:?}");
        }
        let mut levels = BTreeMap::new();
        for tid in [1u64, 2] {
            match conn.close(tid) {
                IngestEvent::Close(task) => {
                    levels.insert(tid, task.lock().unwrap().solve_close().unwrap());
                }
                other => panic!("[{cell}] close {tid}: {other:?}"),
            }
        }
        // Echo phase: interleaved again; windows re-ordered client-side.
        let mut windows: BTreeMap<(u64, u64), Vec<u8>> = BTreeMap::new();
        for (tid, ci, data) in [(2u64, 0u64, &b), (1, 1, &a), (2, 1, &b), (1, 0, &a)] {
            match conn.chunk(tid, ci, ingest::chunk_of(data, ci)) {
                IngestEvent::Payload { chunk_idx, payload, .. } => {
                    assert_eq!(chunk_idx, ci);
                    windows.insert((tid, ci), payload);
                }
                other => panic!("[{cell}] echo {tid}/{ci}: {other:?}"),
            }
        }
        for (tid, want) in [(1u64, &want_a), (2u64, &want_b)] {
            let q = levels.remove(&tid).unwrap();
            let mut payload = Vec::new();
            for ci in 0..2u64 {
                payload.extend_from_slice(&windows[&(tid, ci)]);
            }
            let bits = sq::codec::bits_for(q.len());
            let got = sq::CompressedVec { d: d as u64, q, bits, payload };
            assert_eq!(&got, want, "[{cell}] tenant {tid} must match its monolithic run");
        }
    });
}

#[test]
fn remote_ingest_over_tcp_matches_monolithic() {
    // End-to-end over loopback TCP: the wire choreography (pipelined fill,
    // one IngestSolved, lock-step echo) reassembles the exact monolithic
    // bytes. The service's ingest grid is the router's hist_m = M, so the
    // local reference compares like with like.
    let service = Service::start(ServiceConfig {
        threads: 2,
        router: Router::new(RouterConfig { exact_max_d: 4096, hist_m: M, seed: 3, shards: 1 }),
        ..Default::default()
    })
    .unwrap();
    let d = 2 * par::CHUNK + 777;
    let data = fsample(&Dist::paper_suite()[0].1, d, 9);
    let (want, _) = ingest::monolithic_reference(&data, S, &cfg(), 42).unwrap();
    let (cv, solver, _) = ingest_remote(service.addr(), 42, S, 0, 0, &data).unwrap();
    assert_eq!(cv, want, "TCP ingest must reproduce the monolithic bits");
    assert_eq!(solver, format!("quiver-ingest(M={M})"));
    service.shutdown();
}
