//! The streaming layer's determinism contract, tested end to end
//! (DESIGN.md rule 6):
//!
//! * an N-round replay is **bitwise-identical** across 1/2/4/8 executor
//!   threads × shard counts × forced decision modes (reuse, warm-start,
//!   re-solve) — round-keyed RNG streams make every round a pure function
//!   of `(stream seed, round, data)` plus the processed-round sequence;
//! * whenever a **full re-solve** is triggered, the round's emitted
//!   levels *and* payload are bitwise-identical to the from-scratch path
//!   (`stream::solve_round_from_scratch`) at any thread and shard count;
//! * a property test over perturbed stationary rounds: the drift trigger
//!   never serves cached levels whose objective exceeds the re-solve
//!   result by more than the documented bound
//!   (`stream::reuse_excess_bound`, the `ℓ·d·span²` rule).
//!
//! Tests pin the process-global executor width, so they serialize on one
//! lock (the same pattern as `par_invariance` / `shard_invariance`).

use quiver::dist::Dist;
use quiver::par;
use quiver::stream::{
    reuse_excess_bound, solve_round_from_scratch, Decision, StreamConfig, StreamSolver,
    StreamTuning,
};
use quiver::util::rng::Xoshiro256pp;

/// Serializes tests that pin the global executor width/backend.
static WIDTH_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Restores width and backend even if an assertion panics.
struct ParGuard {
    width: usize,
    backend: par::Backend,
}

impl ParGuard {
    fn pin() -> Self {
        Self { width: par::threads(), backend: par::backend() }
    }
}

impl Drop for ParGuard {
    fn drop(&mut self) {
        par::set_threads(self.width);
        par::set_backend(self.backend);
    }
}

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// One round's full observable output, in bit-exact form.
#[derive(Debug, PartialEq)]
struct RoundSnap {
    decision: u8,
    fallback: bool,
    q: Vec<u64>,
    q_idx: Vec<usize>,
    mse: u64,
    payload: Vec<u8>,
    payload_d: u64,
}

/// Stationary rounds with pinned endpoints (so grids repeat exactly and
/// the reuse tier can engage); a multi-chunk length exercises the
/// executor and the shard plan.
fn round_data(r: u64, d: usize) -> Vec<f64> {
    let mut v = Dist::Uniform { lo: -1.0, hi: 1.0 }.sample_vec(d - 2, 0x1234 + r);
    v.push(-1.5);
    v.push(1.5);
    v
}

/// Replay `rounds` rounds through a fresh solver with the given
/// thresholds and shard count.
fn replay(
    rounds: u64,
    d: usize,
    shards: usize,
    reuse: f64,
    warm: f64,
    cache: usize,
) -> Vec<RoundSnap> {
    let mut solver = StreamSolver::new(StreamConfig {
        m: 257,
        shards,
        tuning: StreamTuning {
            drift_reuse_max: reuse,
            drift_warm_max: warm,
            cache_cap: cache,
            ..StreamTuning::default()
        },
        ..StreamConfig::default()
    });
    (0..rounds)
        .map(|r| {
            let xs = round_data(r, d);
            let (out, payload) = solver.round_compress(r, &xs, 8).expect("round");
            RoundSnap {
                decision: out.decision.code(),
                fallback: out.fallback,
                q: bits(&out.solution.q),
                q_idx: out.solution.q_idx.clone(),
                mse: out.solution.mse.to_bits(),
                payload: payload.payload,
                payload_d: payload.d,
            }
        })
        .collect()
}

/// The tentpole claim: an N-round replay is bitwise-identical across
/// thread counts × shard counts × every decision mode the thresholds can
/// force.
#[test]
fn n_round_replay_bitwise_identical_across_threads_shards_and_decisions() {
    let _guard = WIDTH_LOCK.lock().unwrap();
    let _restore = ParGuard::pin();
    let d = 2 * par::CHUNK + 777;
    let rounds = 5;
    // (reuse, warm, cache) forcing each tier: pure re-solve, warm-start,
    // drift reuse, and the default ladder.
    let modes: [(&str, f64, f64, usize); 4] = [
        ("resolve-only", 0.0, 0.0, 0),
        ("warm-forced", 0.0, f64::INFINITY, 0),
        ("reuse-forced", f64::INFINITY, f64::INFINITY, 0),
        ("default-ladder", 0.05, 0.25, 8),
    ];
    for (mode, reuse, warm, cache) in modes {
        par::set_threads(1);
        let reference = replay(rounds, d, 1, reuse, warm, cache);
        // Every mode actually exercises its tier after round 0.
        match mode {
            "resolve-only" => assert!(
                reference.iter().all(|s| s.decision == Decision::Resolve.code()),
                "{mode}: {:?}",
                reference.iter().map(|s| s.decision).collect::<Vec<_>>()
            ),
            "warm-forced" => assert!(
                reference[1..].iter().all(|s| s.decision == Decision::WarmStart.code()),
                "{mode}"
            ),
            "reuse-forced" => assert!(
                reference[1..].iter().all(|s| s.decision == Decision::Reuse.code()),
                "{mode}"
            ),
            _ => assert!(
                reference[1..].iter().any(|s| s.decision != Decision::Resolve.code()),
                "{mode}: stationary rounds should not all re-solve"
            ),
        }
        for t in [1usize, 2, 4, 8] {
            par::set_threads(t);
            for shards in [1usize, 2, 4] {
                let got = replay(rounds, d, shards, reuse, warm, cache);
                assert_eq!(
                    got, reference,
                    "{mode}: replay diverged at {t} threads, {shards} shards"
                );
            }
        }
    }
}

/// Every re-solve round (and every warm fallback) must be bitwise equal
/// to the stateless from-scratch path, for any thread and shard count.
#[test]
fn resolve_rounds_bitwise_equal_from_scratch() {
    let _guard = WIDTH_LOCK.lock().unwrap();
    let _restore = ParGuard::pin();
    let d = par::CHUNK + 321;
    // Non-stationary rounds (shifting distribution) so plenty of rounds
    // genuinely re-solve even under the default ladder.
    let data = |r: u64| -> Vec<f64> {
        Dist::Normal { mu: r as f64 * 0.5, sigma: 1.0 + 0.2 * r as f64 }.sample_vec(d, 0xAB + r)
    };
    for t in [1usize, 4] {
        par::set_threads(t);
        for shards in [1usize, 3] {
            let cfg = StreamConfig {
                m: 129,
                shards,
                tuning: StreamTuning {
                    drift_reuse_max: 0.0,
                    drift_warm_max: 0.0,
                    cache_cap: 0,
                    ..StreamTuning::default()
                },
                ..StreamConfig::default()
            };
            let mut solver = StreamSolver::new(cfg);
            for r in 0..4u64 {
                let xs = data(r);
                let (out, payload) = solver.round_compress(r, &xs, 8).expect("round");
                assert_eq!(out.decision, Decision::Resolve);
                let (want_sol, want_payload) =
                    solve_round_from_scratch(&cfg, r, &xs, 8).expect("scratch");
                let ctx = format!("round {r}, {t} threads, {shards} shards");
                assert_eq!(out.solution.q_idx, want_sol.q_idx, "{ctx}");
                assert_eq!(bits(&out.solution.q), bits(&want_sol.q), "{ctx}");
                assert_eq!(out.solution.mse.to_bits(), want_sol.mse.to_bits(), "{ctx}");
                assert_eq!(payload, want_payload, "{ctx}");
            }
            assert_eq!(solver.metrics().resolved, 4);
        }
    }
}

/// Rounds processed out of order, or starting mid-stream, still produce
/// the exact per-round streams: a solver that jumps straight to round k
/// re-solves it to the same bits a sequential run re-solves it to.
#[test]
fn round_keying_is_independent_of_history() {
    let _guard = WIDTH_LOCK.lock().unwrap();
    let _restore = ParGuard::pin();
    par::set_threads(2);
    let d = 4000;
    let cfg = StreamConfig {
        m: 65,
        tuning: StreamTuning {
            drift_reuse_max: 0.0,
            drift_warm_max: 0.0,
            cache_cap: 0,
            ..StreamTuning::default()
        },
        ..StreamConfig::default()
    };
    let xs = round_data(6, d);
    // Walked 0..=6 vs jumped straight to 6: round 6 re-solves identically.
    let mut walked = StreamSolver::new(cfg);
    for r in 0..=6u64 {
        walked.round(r, &round_data(r, d), 8).unwrap();
    }
    let mut jumped = StreamSolver::new(cfg);
    let a = walked.round(6, &xs, 8).unwrap();
    let b = jumped.round(6, &xs, 8).unwrap();
    assert_eq!(a.solution.q_idx, b.solution.q_idx);
    assert_eq!(a.solution.mse.to_bits(), b.solution.mse.to_bits());
}

/// The drift property (documented in `stream::hist`): whenever the
/// trigger serves reused levels, their objective on the round's histogram
/// exceeds the exact re-solve's by at most `ℓ·d·span²`. Randomized over
/// perturbation strengths and seeds.
#[test]
fn reuse_never_exceeds_documented_bound() {
    let _guard = WIDTH_LOCK.lock().unwrap();
    let _restore = ParGuard::pin();
    par::set_threads(2);
    let d = 9000;
    let span = 3.0; // pinned sentinels at ±1.5
    let mut rng = Xoshiro256pp::seed_from_u64(0x90B);
    let mut reuses = 0u32;
    for case in 0..6u64 {
        // Random perturbation strength: how much of the interior is
        // redrawn each round (0 = identical data, 1 = fully fresh).
        let frac = rng.next_f64();
        let cfg = StreamConfig {
            m: 127,
            tuning: StreamTuning {
                drift_reuse_max: 0.2, // generous: force reuse under real drift
                // No warm tier: every anchor is an exact solve, which is
                // the regime the documented bound is stated for.
                drift_warm_max: 0.0,
                cache_cap: 0,
                ..StreamTuning::default()
            },
            ..StreamConfig::default()
        };
        let mut solver = StreamSolver::new(cfg);
        let base_round = round_data(1000 + case, d);
        solver.round(0, &base_round, 8).unwrap();
        for r in 1..5u64 {
            let mut xs = base_round.clone();
            // Redraw a prefix of the interior (sentinels untouched).
            let redraw = ((d - 2) as f64 * frac) as usize;
            let fresh = Dist::Uniform { lo: -1.0, hi: 1.0 }
                .sample_vec(redraw, 0x5000 + case * 100 + r);
            xs[..redraw].copy_from_slice(&fresh);
            let out = solver.round(r, &xs, 8).unwrap();
            if out.decision != Decision::Reuse {
                continue;
            }
            reuses += 1;
            let (exact, _) = solve_round_from_scratch(&cfg, r, &xs, 8).unwrap();
            // The bound is stated in accumulated drift since the levels
            // were last solved (chains of reuses telescope).
            let bound = reuse_excess_bound(out.accum_l1, d, span);
            assert!(
                out.solution.mse <= exact.mse + bound + 1e-9 * exact.mse.max(1.0),
                "case {case} round {r} (Σℓ={}): served {} vs exact {} + bound {bound}",
                out.accum_l1,
                out.solution.mse,
                exact.mse
            );
        }
    }
    assert!(reuses >= 5, "the property needs real reuse coverage, saw {reuses}");
}

/// Warm rounds honor the objective bracket, and their quality degrades
/// gracefully: the served objective never beats the exact optimum and
/// stays within bracket + drift slack of it.
#[test]
fn warm_rounds_bracket_and_quality() {
    let _guard = WIDTH_LOCK.lock().unwrap();
    let _restore = ParGuard::pin();
    par::set_threads(2);
    let d = 8000;
    let cfg = StreamConfig {
        m: 127,
        tuning: StreamTuning {
            drift_reuse_max: 0.0, // skip straight past reuse
            drift_warm_max: f64::INFINITY,
            cache_cap: 0,
            ..StreamTuning::default()
        },
        ..StreamConfig::default()
    };
    let mut solver = StreamSolver::new(cfg);
    let mut prev_mse: Option<f64> = None;
    for r in 0..6u64 {
        let xs = round_data(r, d);
        let out = solver.round(r, &xs, 8).unwrap();
        let (exact, _) = solve_round_from_scratch(&cfg, r, &xs, 8).unwrap();
        assert!(
            out.solution.mse + 1e-9 >= exact.mse,
            "round {r}: served objective cannot beat the optimum"
        );
        if r > 0 {
            assert_eq!(out.decision, Decision::WarmStart, "round {r}");
            if !out.fallback {
                let bracket = prev_mse.unwrap() * (1.0 + cfg.tuning.warm_slack) + 1e-12;
                assert!(
                    out.solution.mse <= bracket,
                    "round {r}: accepted warm candidate must honor the bracket"
                );
            } else {
                // A fallback is the exact solve.
                assert_eq!(out.solution.mse.to_bits(), exact.mse.to_bits(), "round {r}");
            }
        }
        prev_mse = Some(out.solution.mse);
    }
}
