//! `cargo bench --bench bench_serve` — serving front-end load test:
//! thread-per-connection (`--frontend threads`) vs the epoll event loop
//! (`--frontend epoll`) under the same open-loop workload.
//!
//! The load generator models a multi-tenant front-end population:
//!
//! * **Open-loop arrivals**: each connection schedules its requests on a
//!   seeded exponential (Poisson-ish) clock and never waits for the
//!   previous reply to fall due — a slow server makes the client *late*,
//!   not idle, so queueing shows up in the tail instead of hiding in the
//!   arrival rate. The aggregate offered rate is held constant across
//!   connection counts (per-connection gaps scale with the population).
//! * **Heavy-tailed tenant sizes**: request dimension is Pareto-ish
//!   (most requests small, rare requests ~100× larger), the shape that
//!   makes per-connection threads block unfairly.
//! * **Deadline-class mix**: 70% best-effort, 20% class 1 with a 100 ms
//!   deadline, 10% class 2 with a 20 ms deadline — exercising the
//!   scheduler's class ordering under load.
//!
//! Each (front-end × connection count) cell reports completed/s, Busy
//! sheds, and client-observed p50/p99/p999 end-to-end latency; the
//! server's own `StatsRequest` snapshot (queue-wait/solve/e2e quantiles)
//! is fetched over the wire at the end of every cell. Machine-readable
//! results land in `BENCH_serve.json` at the repo root.
//!
//! Full mode sweeps 64/512/4096 concurrent connections and asserts the
//! acceptance bar at 4096: the epoll front-end sustains at least the
//! threaded throughput with a lower p999. 4096 connections need ~9000
//! file descriptors in this process plus the server's — raise the limit
//! first (`ulimit -n 32768`). Set `QUIVER_SMOKE=1` for a
//! seconds-long 16/64-connection sweep with no acceptance assert (the CI
//! perf-smoke job and `make bench-serve` use this).

use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use quiver::benchfw::{write_bench_json, BenchRecord, Stats, Table};
use quiver::coordinator::protocol::{recv, send, Msg};
use quiver::coordinator::router::{Router, RouterConfig};
use quiver::coordinator::service::{stats_remote, Frontend, Service, ServiceConfig};
use quiver::util::rng::Xoshiro256pp;

/// Pareto-ish request dimension: xm=512, alpha≈1.1, capped at 48k.
fn heavy_tail_d(rng: &mut Xoshiro256pp) -> usize {
    let u = rng.next_f64_open();
    ((512.0 * u.powf(-1.0 / 1.1)) as usize).clamp(512, 48 * 1024)
}

/// Deadline-class mix: (class, deadline_ms).
fn class_mix(rng: &mut Xoshiro256pp) -> (u8, u32) {
    let roll = rng.next_f64();
    if roll < 0.10 {
        (2, 20)
    } else if roll < 0.30 {
        (1, 100)
    } else {
        (0, 0)
    }
}

/// One cell's client-side outcome.
struct RunResult {
    completed: u64,
    busy: u64,
    wall: Duration,
    /// Sorted client-observed end-to-end latencies, µs.
    lat_us: Vec<u64>,
}

impl RunResult {
    fn quantile_us(&self, q: f64) -> u64 {
        if self.lat_us.is_empty() {
            return 0;
        }
        let idx = ((self.lat_us.len() - 1) as f64 * q).round() as usize;
        self.lat_us[idx]
    }

    fn per_sec(&self) -> f64 {
        self.completed as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

/// Drive `conns` persistent connections of `reqs` open-loop requests each
/// against a fresh service running `frontend`.
fn run_cell(frontend: Frontend, conns: usize, reqs: usize, mean_gap_us: u64) -> RunResult {
    let service = Service::start(ServiceConfig {
        threads: 4,
        queue_capacity: 512,
        frontend,
        // Open-loop gaps at large populations stretch past the default
        // idle deadline; a generous one keeps connections alive without
        // disabling the slow-client sweeps under test elsewhere.
        io_timeout: Duration::from_secs(120),
        router: Router::new(RouterConfig { exact_max_d: 4096, hist_m: 400, seed: 3, shards: 1 }),
        ..Default::default()
    })
    .expect("service");
    let addr = service.addr().to_string();
    // Shared request payload pool: slicing one base vector keeps client
    // CPU out of the measurement (values are irrelevant to serving cost).
    let base: Arc<Vec<f32>> = {
        let mut rng = Xoshiro256pp::stream(0x5E44E, 0);
        Arc::new((0..48 * 1024).map(|_| rng.next_f64() as f32).collect())
    };
    let t0 = Instant::now();
    let mut joins = Vec::with_capacity(conns);
    for i in 0..conns {
        let addr = addr.clone();
        let base = base.clone();
        joins.push(
            std::thread::Builder::new()
                .stack_size(256 << 10)
                .name(format!("load-{i}"))
                .spawn(move || client_conn(&addr, &base, i as u64, reqs, mean_gap_us))
                .expect("spawn load thread"),
        );
    }
    let mut completed = 0u64;
    let mut busy = 0u64;
    let mut lat_us: Vec<u64> = Vec::new();
    for j in joins {
        let (lats, b) = j.join().expect("load thread");
        completed += lats.len() as u64;
        busy += b;
        lat_us.extend(lats);
    }
    let wall = t0.elapsed();
    lat_us.sort_unstable();
    // Server-side stats over the wire: exercises StatsRequest/StatsReply
    // on whichever front-end this cell runs.
    let snap = stats_remote(&addr, 0xBE7C4).expect("stats over the wire");
    println!(
        "  server: accepted={} completed={} shed={} conns={} queue p99={}µs solve p99={}µs \
         e2e p99={}µs",
        snap.accepted,
        snap.completed,
        snap.shed,
        snap.conns_accepted,
        snap.queue_p99_us,
        snap.solve_p99_us,
        snap.e2e_p99_us
    );
    service.shutdown();
    RunResult { completed, busy, wall, lat_us }
}

/// One persistent connection: `reqs` requests on an exponential arrival
/// clock, returning (latencies µs, busy count).
fn client_conn(addr: &str, base: &[f32], idx: u64, reqs: usize, mean_gap_us: u64) -> (Vec<u64>, u64) {
    let mut rng = Xoshiro256pp::stream(0x10AD, idx);
    let sock = TcpStream::connect(addr).expect("connect");
    sock.set_nodelay(true).ok();
    sock.set_read_timeout(Some(Duration::from_secs(60))).ok();
    sock.set_write_timeout(Some(Duration::from_secs(60))).ok();
    let mut rd = std::io::BufReader::new(sock.try_clone().expect("clone"));
    let mut wr = sock;
    let mut lats = Vec::with_capacity(reqs);
    let mut busy = 0u64;
    let mut next_at = Instant::now();
    for r in 0..reqs {
        let gap = (-rng.next_f64_open().ln() * mean_gap_us as f64) as u64;
        next_at += Duration::from_micros(gap);
        let now = Instant::now();
        if next_at > now {
            std::thread::sleep(next_at - now);
        }
        let d = heavy_tail_d(&mut rng);
        let (class, deadline_ms) = class_mix(&mut rng);
        let req = Msg::CompressRequest {
            request_id: r as u64,
            s: 16,
            class,
            deadline_ms,
            data: base[..d].to_vec(),
        };
        let t0 = Instant::now();
        send(&mut wr, &req).expect("send");
        match recv(&mut rd).expect("recv") {
            Some(Msg::CompressReply { request_id, .. }) => {
                assert_eq!(request_id, r as u64, "reply order on one connection");
                lats.push(t0.elapsed().as_micros().max(1) as u64);
            }
            Some(Msg::Busy { .. }) => busy += 1,
            other => panic!("unexpected reply: {:?}", other.map(|m| m.kind())),
        }
    }
    (lats, busy)
}

fn main() {
    let smoke = std::env::var("QUIVER_SMOKE").is_ok();
    let repo_root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    let conn_counts: &[usize] = if smoke { &[16, 64] } else { &[64, 512, 4096] };
    let reqs = if smoke { 4 } else { 16 };
    // Hold the aggregate offered rate roughly constant across population
    // sizes: per-connection mean gap grows with the connection count.
    let offered_per_sec: u64 = if smoke { 1_000 } else { 3_000 };

    let mut records: Vec<BenchRecord> = vec![];
    let mut t = Table::new(
        format!("serving front-ends, open-loop load ({reqs} reqs/conn)"),
        &["frontend", "conns", "done/s", "busy", "p50µs", "p99µs", "p999µs"],
    );
    // (conns, threaded result, epoll result) per sweep point.
    let mut cells: Vec<(usize, RunResult, RunResult)> = vec![];
    for &c in conn_counts {
        let mean_gap_us = (c as u64).saturating_mul(1_000_000) / offered_per_sec.max(1);
        let mut pair: Vec<RunResult> = vec![];
        for fe in [Frontend::Threads, Frontend::Epoll] {
            let label = match fe {
                Frontend::Threads => "threads",
                Frontend::Epoll => "epoll",
            };
            println!("== {label} front-end, {c} connections ==");
            let res = run_cell(fe, c, reqs, mean_gap_us);
            t.row(vec![
                label.into(),
                format!("{c}"),
                format!("{:.0}", res.per_sec()),
                format!("{}", res.busy),
                format!("{}", res.quantile_us(0.5)),
                format!("{}", res.quantile_us(0.99)),
                format!("{}", res.quantile_us(0.999)),
            ]);
            // Throughput record: d = completed requests over one wall
            // sample, so elems_per_s is completed/s.
            let wall = Stats { name: format!("serve/{label}/c{c}"), samples: vec![res.wall] };
            records.push(BenchRecord::from_stats(&wall, res.completed as usize, 16));
            for (q, qname) in [(0.5, "p50"), (0.99, "p99"), (0.999, "p999")] {
                let st = Stats {
                    name: format!("serve/{label}/c{c}/{qname}"),
                    samples: vec![Duration::from_micros(res.quantile_us(q))],
                };
                records.push(BenchRecord::from_stats(&st, 0, 0));
            }
            pair.push(res);
        }
        let epoll = pair.pop().unwrap();
        let threaded = pair.pop().unwrap();
        cells.push((c, threaded, epoll));
    }
    t.print();

    // Acceptance bar (full mode only — smoke sizes are noise-dominated):
    // at the largest population the event loop must sustain at least the
    // threaded front-end's throughput with a lower p999.
    if !smoke {
        let (c, threaded, epoll) = cells.last().expect("at least one sweep point");
        let (tput_t, tput_e) = (threaded.per_sec(), epoll.per_sec());
        let (p999_t, p999_e) = (threaded.quantile_us(0.999), epoll.quantile_us(0.999));
        println!(
            "acceptance @ {c} conns: throughput epoll {tput_e:.0}/s vs threads {tput_t:.0}/s, \
             p999 epoll {p999_e}µs vs threads {p999_t}µs"
        );
        assert!(
            tput_e >= tput_t * 0.95,
            "epoll throughput {tput_e:.0}/s fell below threaded {tput_t:.0}/s at {c} conns"
        );
        assert!(
            p999_e <= p999_t,
            "epoll p999 {p999_e}µs above threaded {p999_t}µs at {c} conns"
        );
    }

    let json = write_bench_json(&repo_root.join("BENCH_serve.json"), &records)
        .expect("write BENCH_serve.json");
    println!("wrote {} records to {}", records.len(), json.display());
}
