//! `cargo bench --bench bench_approx` — approximate methods (paper Fig. 2,
//! Fig. 3 and Appendix D figures 9–13).
//!
//! QUIVER-Hist vs ZipML-CP (U/Q), ZipML 2-Apx and ALQ: dimension, s and M
//! sweeps, plus the histogram-size/guarantee study. `QUIVER_MAX_POW`
//! extends the sweeps (default 18; the paper's largest is 2^22).

use quiver::dist::Dist;
use quiver::figures::{self, FigOpts};

fn main() {
    let max_pow: u32 = std::env::var("QUIVER_MAX_POW")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(18);
    let out = std::path::PathBuf::from("results");
    for (i, (name, dist)) in Dist::paper_suite().into_iter().enumerate() {
        let opts = FigOpts {
            dist,
            max_pow: if i == 0 { max_pow } else { max_pow.saturating_sub(4).max(12) },
            seeds: if i == 0 { 5 } else { 3 },
            time_samples: 3,
        };
        println!("\n########## distribution: {name} ##########");
        let ids: &[&str] = if i == 0 {
            &["2", "3a", "3b", "3c", "3d"]
        } else {
            &["3a", "3c"] // appendix subset per distribution
        };
        for id in ids {
            for t in figures::run(id, &opts).expect("figure") {
                t.print();
                let p = t.save_csv(&out).expect("csv");
                println!("saved {}", p.display());
            }
        }
    }
}
