//! `cargo bench --bench bench_ingest` — streaming-ingestion numbers:
//!
//! * chunked ingest (`coordinator::ingest::ingest_local`) vs the
//!   monolithic whole-buffer pipeline on the same input, bit-equality
//!   asserted inline before timing;
//! * the peak-coordinator-memory proxy: the task's allocation high-water
//!   mark (`IngestTask::peak_bytes`) over a multi-chunk ingest, asserted
//!   against the O(M + CHUNK) budget and recorded so the CI perf-smoke
//!   job surfaces it — this is the machine check that the service never
//!   materializes the vector;
//! * the end-to-end ingest RPC over loopback TCP (pipelined fill +
//!   lock-step echo), wire bits asserted against the monolithic run.
//!
//! Machine-readable results land in `BENCH_ingest.json` at the repo root.
//! Set `QUIVER_SMOKE=1` to shrink sizes so a full run finishes in seconds
//! (the CI perf-smoke job and `make bench-smoke` use this).

use quiver::benchfw::{self, write_bench_json, BenchRecord, Table};
use quiver::coordinator::ingest::{self, IngestConfig, IngestTask};
use quiver::coordinator::router::{Router, RouterConfig};
use quiver::coordinator::service::{ingest_remote, Service, ServiceConfig};
use quiver::dist::Dist;
use quiver::par;

fn main() {
    let smoke = std::env::var("QUIVER_SMOKE").is_ok();
    let repo_root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    let mut records: Vec<BenchRecord> = vec![];
    let samples = if smoke { 3 } else { 10 };
    let pow = if smoke { 18 } else { 21 };
    let d = (1usize << pow) + 777; // ragged tail: the general shape
    let s = 16u32;
    let m = 400usize;
    let cfg = IngestConfig { m, ..Default::default() };
    let data: Vec<f32> = Dist::LogNormal { mu: 0.0, sigma: 1.0 }
        .sample_vec(d, 0x1A57)
        .into_iter()
        .map(|x| x as f32)
        .collect();

    // The invariance contract, asserted on the bench input before timing.
    let (want, _) = ingest::monolithic_reference(&data, s, &cfg, 1).expect("monolithic");
    let (got, _) = ingest::ingest_local(&data, s, &cfg, 1, None).expect("chunked");
    assert_eq!(got, want, "chunked ingest diverged from monolithic on the bench input");

    // --- Throughput: chunked fold-on-arrival vs whole-buffer pipeline. ---
    let mut t = Table::new(
        format!("chunked ingest vs monolithic, d=2^{pow}+777, M={m}, s={s}"),
        &["path", "median", "elems/s", "vs monolithic"],
    );
    let mut medians: Vec<f64> = vec![];
    for (label, chunked) in [("monolithic", false), ("chunked", true)] {
        let st = benchfw::bench(&format!("ingest-{label} d=2^{pow}"), 1, samples, || {
            if chunked {
                ingest::ingest_local(&data, s, &cfg, 1, None).unwrap().0.payload.len()
            } else {
                ingest::monolithic_reference(&data, s, &cfg, 1).unwrap().0.payload.len()
            }
        });
        medians.push(st.median().as_secs_f64());
        let vs = format!("{:.2}x", medians[0] / medians.last().unwrap());
        t.row(vec![
            label.into(),
            benchfw::fmt_duration(st.median()),
            format!("{:.3e}", st.throughput(d)),
            vs,
        ]);
        records.push(BenchRecord::from_stats(&st, d, s as usize));
    }
    t.print();

    // --- Peak coordinator memory: the O(M + CHUNK) proxy. ---
    // One full task lifecycle through the real state machine, tracking the
    // allocation high-water mark. The budget mirrors the module's unit
    // bound: grid counts + one in-flight chunk's transient buffers + one
    // 40-byte record per chunk — and must stay far below d·4 (the bytes a
    // materialized vector would pin).
    {
        let n = d.div_ceil(par::CHUNK) as u64;
        let (lo, hi) = ingest::declared_range(&data);
        let mut task = IngestTask::new(&cfg, 1, d as u64, s, lo, hi).expect("open");
        for ci in 0..n {
            task.add_chunk(ci, ingest::chunk_of(&data, ci)).expect("fold");
        }
        task.close().expect("close");
        task.solve_close().expect("solve");
        let mut payload = 0usize;
        for ci in 0..n {
            payload += task.encode_chunk(ci, ingest::chunk_of(&data, ci)).expect("encode").len();
        }
        let peak = task.peak_bytes();
        let budget = (m + 1) * 8 * 2           // counts + count-pass return
            + par::CHUNK * (4 + 8 + 4)          // frame + widened + indices
            + n as usize * 40                   // scan slots + echo markers
            + par::CHUNK * 4                    // packed window (≤ 4B/coord)
            + 4096; // levels + slack
        assert!(peak <= budget, "peak {peak}B exceeds the O(M + CHUNK) budget {budget}B");
        assert!(
            peak < d * 4,
            "peak {peak}B must stay far below the materialized vector ({}B)",
            d * 4
        );
        println!(
            "ingest peak resident: {peak} B over {n} chunks (budget {budget} B; the \
             vector itself would pin {} B; payload streamed out: {payload} B)",
            d * 4
        );
        let st = benchfw::Stats {
            name: format!("ingest-peak-bytes={peak} budget={budget}"),
            samples: vec![std::time::Duration::from_nanos(peak as u64)],
        };
        records.push(BenchRecord::from_stats(&st, d, s as usize));
    }

    // --- End-to-end ingest RPC (loopback TCP). ---
    {
        let service = Service::start(ServiceConfig {
            threads: 2,
            router: Router::new(RouterConfig { exact_max_d: 4096, hist_m: m, seed: 3, shards: 1 }),
            ..Default::default()
        })
        .expect("service");
        let addr = service.addr().to_string();
        let st = benchfw::bench(&format!("ingest-rpc d=2^{pow}"), 1, samples, || {
            ingest_remote(&addr, 1, s, 0, 0, &data).expect("ingest rpc").0.payload.len()
        });
        let (cv, solver, _) = ingest_remote(&addr, 1, s, 0, 0, &data).expect("ingest rpc");
        assert_eq!(cv, want, "wire ingest diverged from the monolithic run");
        println!("ingest RPC ({solver}): median {}", benchfw::fmt_duration(st.median()));
        records.push(BenchRecord::from_stats(&st, d, s as usize));
        println!("service metrics: {}", service.metrics.summary());
        service.shutdown();
    }

    let json = write_bench_json(&repo_root.join("BENCH_ingest.json"), &records)
        .expect("write BENCH_ingest.json");
    println!("wrote {} records to {}", records.len(), json.display());
}
