//! `cargo bench --bench bench_solvers` — exact solvers (paper Fig. 1 and
//! Appendix D figures 5–8, all five distributions).
//!
//! Prints the same rows the paper plots: runtime vs d at s ∈ {4, 16} and
//! runtime+vNMSE vs s at d ∈ {2^12, 2^16}. Pass `--max-pow N` via
//! `QUIVER_MAX_POW` to extend the sweep (default 18 keeps a run in
//! minutes; the paper goes to 2^20+).

use quiver::dist::Dist;
use quiver::figures::{self, FigOpts};

fn main() {
    let max_pow: u32 = std::env::var("QUIVER_MAX_POW")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(18);
    let out = std::path::PathBuf::from("results");
    // Main-body figure: LogNormal; appendix: the other four distributions
    // at a reduced sweep to keep `cargo bench` bounded.
    for (i, (name, dist)) in Dist::paper_suite().into_iter().enumerate() {
        let opts = FigOpts {
            dist,
            max_pow: if i == 0 { max_pow } else { max_pow.saturating_sub(4).max(12) },
            seeds: if i == 0 { 5 } else { 3 },
            time_samples: 3,
        };
        println!("\n########## distribution: {name} ##########");
        for id in ["1a", "1b", "1c"] {
            for t in figures::run(id, &opts).expect("figure") {
                t.print();
                let p = t.save_csv(&out).expect("csv");
                println!("saved {}", p.display());
            }
            if i > 0 {
                break; // appendix dists: dimension sweep only
            }
        }
    }
}
