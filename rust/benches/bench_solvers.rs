//! `cargo bench --bench bench_solvers` — exact solvers (paper Fig. 1 and
//! Appendix D figures 5–8, all five distributions).
//!
//! Prints the same rows the paper plots: runtime vs d at s ∈ {4, 16} and
//! runtime+vNMSE vs s at d ∈ {2^12, 2^16}. Pass `--max-pow N` via
//! `QUIVER_MAX_POW` to extend the sweep (default 18 keeps a run in
//! minutes; the paper goes to 2^20+).
//!
//! Also writes `BENCH_solvers.json` at the repo root: one machine-readable
//! record per (solver, d) on the LogNormal workload — plus the row-parallel
//! DP section (serial width-1 vs the configured executor, large `s`) — so
//! the exact-solver perf trajectory is diffable across commits.

use quiver::avq::{self, Prefix, SolverKind};
use quiver::benchfw::{self, write_bench_json, BenchRecord};
use quiver::dist::Dist;
use quiver::figures::{self, FigOpts};
use quiver::par;

fn main() {
    let max_pow: u32 = std::env::var("QUIVER_MAX_POW")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(18);
    let out = std::path::PathBuf::from("results");
    // Main-body figure: LogNormal; appendix: the other four distributions
    // at a reduced sweep to keep `cargo bench` bounded.
    for (i, (name, dist)) in Dist::paper_suite().into_iter().enumerate() {
        let opts = FigOpts {
            dist,
            max_pow: if i == 0 { max_pow } else { max_pow.saturating_sub(4).max(12) },
            seeds: if i == 0 { 5 } else { 3 },
            time_samples: 3,
        };
        println!("\n########## distribution: {name} ##########");
        for id in ["1a", "1b", "1c"] {
            for t in figures::run(id, &opts).expect("figure") {
                t.print();
                let p = t.save_csv(&out).expect("csv");
                println!("saved {}", p.display());
            }
            if i > 0 {
                break; // appendix dists: dimension sweep only
            }
        }
    }

    // --- Machine-readable perf records (LogNormal, s = 16). ---
    let s = 16usize;
    let mut records: Vec<BenchRecord> = vec![];
    let dist = Dist::LogNormal { mu: 0.0, sigma: 1.0 };
    for pow in [12u32, 14, 16, 18] {
        if pow > max_pow {
            break;
        }
        let d = 1usize << pow;
        let xs = dist.sample_sorted(d, 1);
        let p = Prefix::unweighted(&xs);
        for kind in [SolverKind::BinSearch, SolverKind::Quiver, SolverKind::QuiverAccel] {
            let st = benchfw::bench(&format!("{} d=2^{pow} s={s}", kind.name()), 1, 3, || {
                avq::solve(&p, s, kind).unwrap()
            });
            records.push(BenchRecord::from_stats(&st, d, s));
        }
    }
    // --- Row-parallel DP layers: 1 thread vs the configured width. ---
    // Each QuiverAccel layer is a SMAWK row-minima solve; above the block
    // cutoff it fans out over the executor (`avq::smawk::row_minima_blocked`)
    // with bit-identical minima, so only wall-clock differs. Large budgets
    // multiply the number of layers — the regime the parallel solve is for.
    {
        let configured = par::threads();
        let pow = max_pow.min(14);
        let d = 1usize << pow;
        let xs = dist.sample_sorted(d, 3);
        let p = Prefix::unweighted(&xs);
        let widths: Vec<usize> = if configured > 1 { vec![1, configured] } else { vec![1] };
        for rs in [64usize, 128] {
            let mut medians: Vec<f64> = vec![];
            for &w in &widths {
                par::set_threads(w);
                let st = benchfw::bench(
                    &format!("accel-rowpar d=2^{pow} s={rs} t={w}"),
                    1,
                    3,
                    || avq::solve(&p, rs, SolverKind::QuiverAccel).unwrap(),
                );
                medians.push(st.median().as_secs_f64());
                let speedup = if medians.len() > 1 {
                    format!(" ({:.2}x vs t=1)", medians[0] / medians.last().unwrap())
                } else {
                    String::new()
                };
                println!(
                    "accel-rowpar d=2^{pow} s={rs} t={w}: {}{speedup}",
                    benchfw::fmt_duration(st.median())
                );
                records.push(BenchRecord::from_stats(&st, d, rs));
            }
        }
        par::set_threads(configured);
    }

    let repo_root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    let json = write_bench_json(&repo_root.join("BENCH_solvers.json"), &records)
        .expect("write BENCH_solvers.json");
    println!("wrote {} records to {}", records.len(), json.display());
}
