//! `cargo bench --bench bench_pipeline` — system-level numbers:
//!
//! * Appendix C (Fig. 4): sort + quantize timings (incl. the PJRT-executed
//!   Pallas `sq` artifact when `make artifacts` has run);
//! * §7 headline: 1M optimal / 133M near-optimal timings;
//! * the data-parallel hot paths at d = 2²⁰: histogram build,
//!   quantize+encode, and sort at 1 thread vs the configured executor
//!   width, with the speedup printed (the `par` acceptance numbers);
//! * spawn-wave vs persistent-pool: the same wave-heavy pass on the
//!   scoped backend (one thread spawn per worker per wave) vs the worker
//!   pool (parked threads, sealed handoff);
//! * multi-tenant small-vector batches: per-call compression vs one
//!   `par::dispatch_batch` wave per batch (the serving path);
//! * the sharded coordinator: the hist solve split across 1/2/4/8
//!   chunk-aligned shard ranges (bit-identical results, asserted), so
//!   the scale-out overhead is measured on its own;
//! * incremental rounds (`quiver::stream`): a 20-round
//!   stationary-distribution replay comparing the streaming solver's
//!   per-round solve cost against a from-scratch solve of the identical
//!   round histogram (the ≥5× cache/warm-start win is asserted), plus
//!   the warm-start iteration-count wins (Bin-Search cost evals, ALQ
//!   sweeps, 2-Apx threshold probes);
//! * coordinator micro-benches: codec, batcher, end-to-end service RPC.
//!
//! Machine-readable results land in `BENCH_pipeline.json`,
//! `BENCH_shard.json` and `BENCH_stream.json` at the repo root (name, d,
//! s, median_ns, mad_ns, elems_per_s per entry; the stream file carries
//! one record per replay round — the round-cost curve).
//!
//! Set `QUIVER_SMOKE=1` to shrink every size so a full run finishes in
//! seconds (the CI perf-smoke job and `make bench-smoke` use this).

use std::time::Duration;

use quiver::avq::histogram::{solve_hist, GridHistogram, HistConfig};
use quiver::benchfw::{self, write_bench_json, BenchRecord, Table};
use quiver::coordinator::protocol::Msg;
use quiver::coordinator::router::{Router, RouterConfig};
use quiver::coordinator::service::{compress_remote, Service, ServiceConfig};
use quiver::dist::Dist;
use quiver::figures::{self, FigOpts};
use quiver::par;
use quiver::sq;
use quiver::util::rng::Xoshiro256pp;

fn main() {
    let smoke = std::env::var("QUIVER_SMOKE").is_ok();
    let out = std::path::PathBuf::from("results");
    let repo_root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    let mut records: Vec<BenchRecord> = vec![];

    let opts = if smoke {
        FigOpts { max_pow: 13, seeds: 1, time_samples: 1, ..FigOpts::default() }
    } else {
        FigOpts::default()
    };
    for id in ["4", "headline"] {
        for t in figures::run(id, &opts).expect("figure") {
            t.print();
            let p = t.save_csv(&out).expect("csv");
            println!("saved {}", p.display());
        }
    }

    // --- Data-parallel hot paths: 1 thread vs the configured width. ---
    // Smoke still needs > RUN elements (and several chunks), or every pass
    // would take its sequential fallback and record a meaningless 1.00x.
    let configured = par::threads();
    let hot_pow = if smoke { 19 } else { 20 };
    let d = 1usize << hot_pow;
    let s = 16usize;
    let samples = if smoke { 3 } else { 10 };
    let xs = Dist::LogNormal { mu: 0.0, sigma: 1.0 }.sample_vec(d, 42);
    let qs = solve_hist(&xs, s, &HistConfig::fixed(1024)).expect("hist solve").q;
    let mut t = Table::new(
        format!("parallel hot paths, d=2^{hot_pow} (speedup = t1/tN)"),
        &["pass", "threads", "median", "elems/s", "speedup"],
    );
    let thread_counts: Vec<usize> =
        if configured > 1 { vec![1, configured] } else { vec![1] };
    // (pass, quantization budget for the JSON record — 0 when none applies)
    for (pass, rec_s) in [("hist-build", 0usize), ("quantize+encode", s), ("sort", 0)] {
        let mut medians: Vec<(usize, f64)> = vec![];
        for &tc in &thread_counts {
            par::set_threads(tc);
            let name = format!("{pass} d=2^{hot_pow} t={tc}");
            let st = match pass {
                "hist-build" => benchfw::bench(&name, 1, samples, || {
                    let mut rng = Xoshiro256pp::seed_from_u64(9);
                    GridHistogram::build(&xs, 1024, &mut rng).unwrap()
                }),
                "quantize+encode" => benchfw::bench(&name, 1, samples, || {
                    let mut rng = Xoshiro256pp::seed_from_u64(11);
                    let idx = sq::quantize(&xs, &qs, &mut rng);
                    sq::encode(&idx, &qs)
                }),
                _ => {
                    // One pristine copy per iteration, cloned OUTSIDE the
                    // timed closure — the speedup must not be diluted by a
                    // constant memcpy (and re-sorting sorted data would
                    // measure a different algorithm path entirely).
                    let mut pool: Vec<Vec<f64>> =
                        (0..samples + 1).map(|_| xs.clone()).collect();
                    let mut next = 0usize;
                    benchfw::bench(&name, 1, samples, || {
                        let v = &mut pool[next];
                        next += 1;
                        par::sort::sort_f64(v);
                    })
                }
            };
            medians.push((tc, st.median().as_secs_f64()));
            let speedup = if medians.len() > 1 {
                format!("{:.2}x", medians[0].1 / medians.last().unwrap().1)
            } else {
                "1.00x".into()
            };
            t.row(vec![
                pass.into(),
                tc.to_string(),
                benchfw::fmt_duration(st.median()),
                format!("{:.3e}", st.throughput(d)),
                speedup,
            ]);
            records.push(BenchRecord::from_stats(&st, d, rec_s));
        }
    }
    par::set_threads(configured);
    t.print();

    // --- SIMD vs scalar chunk kernels. ---
    // The vectorized kernels are bit-identical to scalar by contract
    // (tests/simd_parity.rs), so only throughput is compared here. On a
    // CPU without AVX2 the section benches scalar twice (speedup 1.00x)
    // instead of vanishing, keeping the JSON schema stable across
    // machines.
    {
        let mut t = Table::new(
            format!("SIMD vs scalar chunk kernels, d=2^{hot_pow} (speedup = scalar/simd)"),
            &["kernel", "mode", "median", "elems/s", "speedup"],
        );
        let prev_mode = par::simd::simd();
        let modes = if par::simd::detected_avx2() {
            vec![par::simd::SimdMode::Scalar, par::simd::SimdMode::Avx2]
        } else {
            vec![par::simd::SimdMode::Scalar, par::simd::SimdMode::Scalar]
        };
        // Shared fixtures: a dequantize index stream over the s=16 levels,
        // and a u8-aligned stream (s=256) for the byte-pack fast path.
        let idx_deq = {
            let mut rng = Xoshiro256pp::seed_from_u64(11);
            sq::quantize(&xs, &qs, &mut rng)
        };
        let (xlo, xhi) = xs
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &x| (l.min(x), h.max(x)));
        let qs256: Vec<f64> =
            (0..256).map(|i| xlo + (xhi - xlo) * i as f64 / 255.0).collect();
        let idx8 = {
            let mut rng = Xoshiro256pp::seed_from_u64(23);
            sq::quantize(&xs, &qs256, &mut rng)
        };
        for (kernel, rec_s) in
            [("fused-scan", 0usize), ("quantize", s), ("dequantize", s), ("pack-u8", 256)]
        {
            let mut medians: Vec<f64> = vec![];
            for &mode in &modes {
                par::simd::set_simd(mode);
                let name = format!("{kernel} d=2^{hot_pow} simd={}", mode.name());
                let st = match kernel {
                    "fused-scan" => {
                        benchfw::bench(&name, 1, samples, || par::scan::stats(&xs))
                    }
                    "quantize" => benchfw::bench(&name, 1, samples, || {
                        let mut rng = Xoshiro256pp::seed_from_u64(11);
                        sq::quantize(&xs, &qs, &mut rng)
                    }),
                    "dequantize" => {
                        benchfw::bench(&name, 1, samples, || sq::dequantize(&idx_deq, &qs))
                    }
                    _ => benchfw::bench(&name, 1, samples, || sq::encode(&idx8, &qs256)),
                };
                medians.push(st.median().as_secs_f64());
                let speedup = if medians.len() > 1 {
                    format!("{:.2}x", medians[0] / medians.last().unwrap())
                } else {
                    "1.00x".into()
                };
                t.row(vec![
                    kernel.into(),
                    mode.name().into(),
                    benchfw::fmt_duration(st.median()),
                    format!("{:.3e}", st.throughput(d)),
                    speedup,
                ]);
                records.push(BenchRecord::from_stats(&st, d, rec_s));
            }
        }
        par::simd::set_simd(prev_mode);
        t.print();
    }

    // --- Spawn-wave vs persistent pool. ---
    // A wave-heavy workload: many back-to-back chunked passes over a
    // mid-size vector, so per-wave overhead (thread spawn+join vs sealed
    // queue handoff to parked workers) dominates the comparison. Outputs
    // are bitwise-identical by the executor contract; only overhead
    // differs.
    {
        let wave_d = 1usize << if smoke { 17 } else { 18 };
        let passes = if smoke { 8 } else { 32 };
        let ys = Dist::Normal { mu: 0.0, sigma: 1.0 }.sample_vec(wave_d, 77);
        let mut t = Table::new(
            format!("spawn-wave vs pool, {passes}×scan of d=2^{}", wave_d.trailing_zeros()),
            &["backend", "median", "elems/s", "speedup"],
        );
        let run_passes = || {
            let mut acc = 0.0f64;
            for _ in 0..passes {
                acc += par::scan::stats(&ys).norm2_sq;
            }
            acc
        };
        let mut medians: Vec<f64> = vec![];
        let prev_backend = par::backend();
        for (label, backend) in
            [("scoped-spawn", par::Backend::Scoped), ("pool", par::Backend::Pool)]
        {
            par::set_backend(backend);
            let st =
                benchfw::bench(&format!("{passes}x scan {label}"), 1, samples, || run_passes());
            medians.push(st.median().as_secs_f64());
            let speedup = if medians.len() > 1 {
                format!("{:.2}x", medians[0] / medians.last().unwrap())
            } else {
                "1.00x".into()
            };
            t.row(vec![
                label.into(),
                benchfw::fmt_duration(st.median()),
                format!("{:.3e}", st.throughput(wave_d * passes)),
                speedup,
            ]);
            records.push(BenchRecord::from_stats(&st, wave_d * passes, 0));
        }
        par::set_backend(prev_backend);
        t.print();
    }

    // --- Multi-tenant small-vector batches (the serving path). ---
    // A batch of 1K-element tenant vectors: compressing them one at a
    // time leaves tenant-level parallelism on the table (each vector is
    // below one executor chunk, so its own passes run sequentially);
    // `dispatch_batch` packs the whole batch into one sealed pool wave.
    {
        let tenants_n = if smoke { 128 } else { 512 };
        let tenant_d = 1024usize;
        let vecs: Vec<Vec<f64>> = (0..tenants_n as u64)
            .map(|t| Dist::LogNormal { mu: 0.0, sigma: 1.0 }.sample_vec(tenant_d, 1000 + t))
            .collect();
        let qsets: Vec<Vec<f64>> = vecs
            .iter()
            .map(|xs| solve_hist(xs, 16, &HistConfig::fixed(256)).expect("tenant solve").q)
            .collect();
        let tenants: Vec<(&[f64], &[f64])> = vecs
            .iter()
            .zip(&qsets)
            .map(|(xs, qs)| (xs.as_slice(), qs.as_slice()))
            .collect();
        let mut t = Table::new(
            format!("small-vector batch: {tenants_n} tenants × d={tenant_d}, s=16"),
            &["path", "median", "tenants/s", "speedup", "pool waves/batch"],
        );
        let mut medians: Vec<f64> = vec![];
        let mut bench_one = |label: &str,
                             medians: &mut Vec<f64>,
                             t: &mut Table,
                             records: &mut Vec<BenchRecord>,
                             f: &mut dyn FnMut() -> usize| {
            let waves0 = par::pool::wave_count();
            let mut calls = 0usize;
            let st = benchfw::bench(label, 1, samples, || {
                calls += 1;
                f()
            });
            let waves_per_batch =
                (par::pool::wave_count() - waves0) as f64 / (calls as f64).max(1.0);
            medians.push(st.median().as_secs_f64());
            let speedup = if medians.len() > 1 {
                format!("{:.2}x", medians[0] / medians.last().unwrap())
            } else {
                "1.00x".into()
            };
            t.row(vec![
                label.into(),
                benchfw::fmt_duration(st.median()),
                format!("{:.3e}", st.throughput(tenants_n)),
                speedup,
                format!("{waves_per_batch:.1}"),
            ]);
            records.push(BenchRecord::from_stats(&st, tenants_n * tenant_d, 16));
        };
        // (a) one vector at a time, per-tenant derived streams (the exact
        // computation the batch performs, minus the batching).
        bench_one("per-call loop", &mut medians, &mut t, &mut records, &mut || {
            let mut rng = Xoshiro256pp::seed_from_u64(5);
            let base = rng.next_u64();
            tenants
                .iter()
                .enumerate()
                .map(|(j, (xs, qs))| {
                    sq::compress(xs, qs, &mut Xoshiro256pp::stream(base, j as u64)).payload.len()
                })
                .sum()
        });
        // (b) batched dispatch on the scoped backend (one spawn wave per
        // batch — already amortized, but spawning per call).
        let prev_backend = par::backend();
        par::set_backend(par::Backend::Scoped);
        bench_one("dispatch (scoped)", &mut medians, &mut t, &mut records, &mut || {
            let mut rng = Xoshiro256pp::seed_from_u64(5);
            sq::compress_batch(tenants.clone(), &mut rng).iter().map(|c| c.payload.len()).sum()
        });
        // (c) batched dispatch on the persistent pool (one sealed handoff).
        par::set_backend(par::Backend::Pool);
        bench_one("dispatch (pool)", &mut medians, &mut t, &mut records, &mut || {
            let mut rng = Xoshiro256pp::seed_from_u64(5);
            sq::compress_batch(tenants.clone(), &mut rng).iter().map(|c| c.payload.len()).sum()
        });
        par::set_backend(prev_backend);
        t.print();
    }

    // --- Sharded coordinator (the 10⁸-coordinate scale-out path at
    // bench-size d). Results are bitwise-identical for every shard count
    // — asserted once below — so the table is pure scheduling overhead:
    // the cost of the split + exact merges on one machine. Records land
    // in BENCH_shard.json so the shard layer gets its own perf
    // trajectory.
    {
        use quiver::coordinator::shard::{ShardConfig, ShardCoordinator};
        let shard_pow = if smoke { 18 } else { 22 };
        let d = 1usize << shard_pow;
        let xs = Dist::LogNormal { mu: 0.0, sigma: 1.0 }.sample_vec(d, 4242);
        let mut t = Table::new(
            format!("sharded hist solve, d=2^{shard_pow}, M=1024, s=16"),
            &["shards", "median", "elems/s", "vs 1 shard"],
        );
        let mut shard_records: Vec<BenchRecord> = vec![];
        let mut medians: Vec<f64> = vec![];
        let mut ref_mse: Option<u64> = None;
        for shards in [1usize, 2, 4, 8] {
            let coord = ShardCoordinator::new(ShardConfig {
                shards,
                m: 1024,
                ..Default::default()
            });
            let st = benchfw::bench(
                &format!("sharded-solve d=2^{shard_pow} shards={shards}"),
                1,
                samples,
                || coord.solve(&xs, 16).expect("sharded solve"),
            );
            // Shard invariance, proven in-line on the bench input.
            let mse_bits = coord.solve(&xs, 16).expect("sharded solve").mse.to_bits();
            match ref_mse {
                None => ref_mse = Some(mse_bits),
                Some(want) => assert_eq!(mse_bits, want, "shards={shards} diverged"),
            }
            medians.push(st.median().as_secs_f64());
            let vs1 = format!("{:.2}x", medians[0] / medians.last().unwrap());
            t.row(vec![
                shards.to_string(),
                benchfw::fmt_duration(st.median()),
                format!("{:.3e}", st.throughput(d)),
                vs1,
            ]);
            shard_records.push(BenchRecord::from_stats(&st, d, 16));
        }
        t.print();

        // Remote fleet recovery on the same input: one dead node of
        // three, driven over loopback TCP through the fault-tolerant
        // driver. Determinism rule 7 is asserted inline (recovered bits
        // == healthy in-process run), and the recovery counters — which
        // are deterministic, the same fault replays identically — ride
        // in the record name so the CI perf-smoke job can surface them.
        {
            use quiver::coordinator::fault::{FleetConfig, FleetState};
            use quiver::coordinator::shard::ShardNode;
            let fcoord =
                ShardCoordinator::new(ShardConfig { m: 1024, ..Default::default() });
            let mut rng = Xoshiro256pp::seed_from_u64(99);
            let want = fcoord.compress(&xs, 16, &mut rng).expect("healthy compress");
            let nodes: Vec<ShardNode> = (0..2)
                .map(|_| ShardNode::start("127.0.0.1:0").expect("shard node"))
                .collect();
            // An address that refuses connections: bind, then drop.
            let dead = {
                let l = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
                l.local_addr().expect("addr").to_string()
            };
            let mut addrs = vec![dead];
            addrs.extend(nodes.iter().map(|n| n.addr().to_string()));
            let net = FleetConfig {
                connect_timeout: Duration::from_millis(500),
                retries: 0,
                ..Default::default()
            };
            let st = benchfw::bench(
                &format!("remote-ft 1-dead-of-3 d=2^{shard_pow}"),
                1,
                samples,
                || {
                    let state = FleetState::new(&net);
                    let mut rng = Xoshiro256pp::seed_from_u64(99);
                    fcoord
                        .compress_remote_ft(&addrs, &xs, 16, &mut rng, &net, &state)
                        .expect("fleet recovery")
                },
            );
            let state = FleetState::new(&net);
            let mut rng = Xoshiro256pp::seed_from_u64(99);
            let got = fcoord
                .compress_remote_ft(&addrs, &xs, 16, &mut rng, &net, &state)
                .expect("fleet recovery");
            assert_eq!(got.1, want.1, "recovered payload diverged from the healthy run");
            let (f, r, b, l) = state.stats.snapshot();
            println!(
                "remote-ft recovery: {} over 2 survivors, median {}",
                state.stats.summary(),
                benchfw::fmt_duration(st.median()),
            );
            let mut rec = BenchRecord::from_stats(&st, d, 16);
            rec.name = format!("{} fault={f} retry={r} breaker={b} fallback={l}", rec.name);
            shard_records.push(rec);
            for n in nodes {
                n.shutdown();
            }
        }

        let json = write_bench_json(&repo_root.join("BENCH_shard.json"), &shard_records)
            .expect("write BENCH_shard.json");
        println!("wrote {} records to {}", shard_records.len(), json.display());
    }

    // --- Incremental rounds (`quiver::stream`): the multi-round section.
    // A 20-round stationary replay (fresh sample of the same distribution
    // per round, endpoints pinned so the grid repeats): round 0 re-solves
    // from scratch; later rounds are served by the drift tracker — cache,
    // reuse, or warm start. Each round's streaming solve cost is compared
    // against a from-scratch solve of the *identical* round histogram, so
    // the table isolates the solve-side win (the O(d) histogram build is
    // paid identically on both sides). Per-round records land in
    // BENCH_stream.json — the round-cost curve EXPERIMENTS.md documents.
    {
        use quiver::avq::binsearch;
        use quiver::avq::histogram::solve_on;
        use quiver::avq::SolverKind;
        use quiver::baselines::{alq, zipml_2apx};
        use quiver::stream::{self, StreamConfig, StreamSolver};

        let round_pow = if smoke { 17 } else { 20 };
        let d = 1usize << round_pow;
        let rounds = 20u64;
        let m = if smoke { 512 } else { 1024 };
        let s = 16usize;
        // Stationary gradient-style rounds: a fixed base sample with 1/8
        // of the coordinates redrawn per round (Faghri et al.'s regime —
        // consecutive rounds statistically near-identical) and sentinel
        // endpoints pinning the grid so rounds share it exactly.
        let base_sample = Dist::Uniform { lo: -1.0, hi: 1.0 }.sample_vec(d - 2, 0xF00D);
        let mk_round = |r: u64| -> Vec<f64> {
            let mut v = base_sample.clone();
            let redraw = (d - 2) / 8;
            let fresh = Dist::Uniform { lo: -1.0, hi: 1.0 }.sample_vec(redraw, 0xF00D + 1 + r);
            v[..redraw].copy_from_slice(&fresh);
            v.push(-1.5);
            v.push(1.5);
            v
        };
        let scfg = StreamConfig { m, inner: SolverKind::BinSearch, ..Default::default() };
        let mut solver = StreamSolver::new(scfg);
        let base = stream::stream_base(scfg.seed);
        let mut t = Table::new(
            format!("incremental rounds, d=2^{round_pow}, M={m}, s={s} (stationary replay)"),
            &["round", "decision", "drift", "stream solve", "scratch solve", "speedup"],
        );
        let mut stream_records: Vec<BenchRecord> = vec![];
        let mut fresh_samples: Vec<std::time::Duration> = vec![];
        let (mut stream_after0_us, mut fresh_after0_us) = (0u64, 0u64);
        for r in 0..rounds {
            let xs = mk_round(r);
            let outcome = solver.round(r, &xs, s).expect("stream round");
            // From-scratch reference on the bit-identical round histogram
            // (same round-keyed base), solve step timed on its own.
            let (hist_base, _) = stream::round_bases(base, r);
            let h = GridHistogram::build_with_base(&xs, m, hist_base).expect("round hist");
            let tf = std::time::Instant::now();
            let fresh = solve_on(&h, s, SolverKind::BinSearch).expect("scratch solve");
            let fresh_dt = tf.elapsed();
            let fresh_us = fresh_dt.as_micros().max(1) as u64;
            if outcome.decision == quiver::stream::Decision::Resolve {
                assert_eq!(
                    outcome.solution.mse.to_bits(),
                    fresh.mse.to_bits(),
                    "round {r}: a re-solve must equal the from-scratch solve bitwise"
                );
            }
            if r > 0 {
                stream_after0_us += outcome.solve_us;
                fresh_after0_us += fresh_us;
            }
            let st = benchfw::Stats {
                name: format!("stream round r={r} [{}]", outcome.decision.name()),
                samples: vec![std::time::Duration::from_micros(outcome.solve_us)],
            };
            stream_records.push(BenchRecord::from_stats(&st, d, s));
            fresh_samples.push(fresh_dt);
            t.row(vec![
                r.to_string(),
                outcome.decision.name().into(),
                if outcome.drift_total.is_finite() {
                    format!("{:.4}", outcome.drift_total)
                } else {
                    "-".into()
                },
                format!("{}µs", outcome.solve_us),
                format!("{}µs", fresh_us),
                format!("{:.1}x", fresh_us as f64 / outcome.solve_us.max(1) as f64),
            ]);
        }
        let fresh_st = benchfw::Stats { name: "stream scratch-solve baseline".into(), samples: fresh_samples };
        stream_records.push(BenchRecord::from_stats(&fresh_st, d, s));
        t.print();
        println!("stream decisions: {}", solver.metrics().summary());
        // The acceptance bar: after round 1, cache/warm-start must cut the
        // per-round solve cost by ≥ 5× vs from-scratch.
        let speedup = fresh_after0_us as f64 / stream_after0_us.max(1) as f64;
        println!(
            "rounds 1..{rounds}: stream {stream_after0_us}µs vs scratch {fresh_after0_us}µs \
             ({speedup:.1}x)"
        );
        assert!(
            speedup >= 5.0,
            "incremental rounds must be ≥5x cheaper after round 1, got {speedup:.2}x"
        );
        let json = write_bench_json(&repo_root.join("BENCH_stream.json"), &stream_records)
            .expect("write BENCH_stream.json");
        println!("wrote {} records to {}", stream_records.len(), json.display());

        // Warm-start iteration counts: two consecutive stationary rounds,
        // cold vs warm on each warm-startable solver. Work units, not
        // wall-clock — immune to runner noise.
        let ra = mk_round(100);
        let rb = mk_round(101);
        let (hb_a, _) = stream::round_bases(base, 100);
        let (hb_b, _) = stream::round_bases(base, 101);
        let ha = GridHistogram::build_with_base(&ra, m, hb_a).unwrap();
        let hb = GridHistogram::build_with_base(&rb, m, hb_b).unwrap();
        let pa = ha.prefix();
        let pb = hb.prefix();
        let (_, trace_a) = binsearch::solve_traced(&pa, s);
        let (_, cold_trace) = binsearch::solve_traced(&pb, s);
        let warm = binsearch::solve_warm(&pb, s, &trace_a, 2, 0.05);
        let mut t = Table::new(
            "warm-start iteration counts (round N+1 seeded from round N)",
            &["solver", "unit", "cold", "warm", "win"],
        );
        t.row(vec![
            "binsearch".into(),
            "cost evals".into(),
            cold_trace.evals.to_string(),
            warm.evals.to_string(),
            format!("{:.1}x", cold_trace.evals as f64 / warm.evals.max(1) as f64),
        ]);
        assert!(
            warm.evals < cold_trace.evals,
            "warm DP must evaluate fewer costs: {} vs {}",
            warm.evals,
            cold_trace.evals
        );
        // ALQ / 2-Apx iterate on sorted sample vectors (their own input
        // shape); same two-round regime — round B shares ⅞ of round A's
        // coordinates, so the warm state is genuinely close.
        let sorted_d = if smoke { 4096 } else { 16_384 };
        let bs = 8usize; // baseline budget (coordinate descent mixes slowly past this)
        let base_round = Dist::Normal { mu: 0.3, sigma: 1.2 }.sample_vec(sorted_d, 0xA1);
        let mut sa = base_round.clone();
        sa.sort_unstable_by(f64::total_cmp);
        let mut sb = base_round;
        let fresh = Dist::Normal { mu: 0.3, sigma: 1.2 }.sample_vec(sorted_d / 8, 0xA2);
        sb[..sorted_d / 8].copy_from_slice(&fresh);
        sb.sort_unstable_by(f64::total_cmp);
        let (qa, _) = alq::solve_converged(&sa, bs, 60, 1e-4);
        let (_, alq_cold) = alq::solve_converged(&sb, bs, 60, 1e-4);
        let (_, alq_warm) = alq::solve_warm(&sb, bs, &qa, 60, 1e-4);
        t.row(vec![
            "alq".into(),
            "sweeps".into(),
            alq_cold.to_string(),
            alq_warm.to_string(),
            format!("{:.1}x", alq_cold as f64 / alq_warm.max(1) as f64),
        ]);
        assert!(alq_warm < alq_cold, "warm ALQ must sweep less: {alq_warm} vs {alq_cold}");
        let tsa = zipml_2apx::solve_bracketed(&sa, bs, None, 1e-3);
        let tsb_cold = zipml_2apx::solve_bracketed(&sb, bs, None, 1e-3);
        let tsb_warm = zipml_2apx::solve_bracketed(&sb, bs, Some(tsa.threshold), 1e-3);
        t.row(vec![
            "zipml-2apx".into(),
            "greedy probes".into(),
            tsb_cold.probes.to_string(),
            tsb_warm.probes.to_string(),
            format!("{:.1}x", tsb_cold.probes as f64 / tsb_warm.probes.max(1) as f64),
        ]);
        assert!(
            tsb_warm.probes < tsb_cold.probes,
            "warm bracket must probe less: {} vs {}",
            tsb_warm.probes,
            tsb_cold.probes
        );
        t.print();
    }

    // --- Coordinator micro-benches. ---
    let mut t = Table::new("coordinator micro-benches", &["op", "median", "spread"]);
    // Codec: pack/unpack a 1M-coordinate gradient at 4 bits.
    let qs16: Vec<f64> = (0..16).map(|i| i as f64).collect();
    let idx: Vec<u32> = (0..1 << 20).map(|i| (i % 16) as u32).collect();
    let st = benchfw::bench("encode 1M@4b", 2, samples, || sq::encode(&idx, &qs16));
    t.row(vec![st.name.clone(), benchfw::fmt_duration(st.median()), benchfw::fmt_duration(st.mad())]);
    records.push(BenchRecord::from_stats(&st, idx.len(), 16));
    let packed = sq::encode(&idx, &qs16);
    let st = benchfw::bench("decode 1M@4b", 2, samples, || sq::decode(&packed));
    t.row(vec![st.name.clone(), benchfw::fmt_duration(st.median()), benchfw::fmt_duration(st.mad())]);
    records.push(BenchRecord::from_stats(&st, idx.len(), 16));
    // Frame roundtrip.
    let msg = Msg::CompressRequest {
        request_id: 1,
        s: 16,
        class: 0,
        deadline_ms: 0,
        data: vec![0.5f32; 1 << 16],
    };
    let st = benchfw::bench("frame 64K req", 2, 20, || {
        let f = msg.to_frame();
        Msg::from_body(&f[4..]).unwrap()
    });
    t.row(vec![st.name.clone(), benchfw::fmt_duration(st.median()), benchfw::fmt_duration(st.mad())]);
    records.push(BenchRecord::from_stats(&st, 1 << 16, 0)); // framing, no s
    t.print();

    // --- End-to-end service RPC latency (loopback). ---
    let service = Service::start(ServiceConfig {
        threads: 2,
        queue_capacity: 64,
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        router: Router::new(RouterConfig { exact_max_d: 1 << 14, hist_m: 400, seed: 3, shards: 1 }),
        ..Default::default()
    })
    .expect("service");
    let addr = service.addr().to_string();
    let mut t = Table::new("service RPC (loopback)", &["request", "median", "spread"]);
    for (label, d) in [("8K exact", 8_192usize), ("256K hist", 262_144)] {
        let data: Vec<f32> = Dist::LogNormal { mu: 0.0, sigma: 1.0 }
            .sample_vec(d, 7)
            .into_iter()
            .map(|x| x as f32)
            .collect();
        let st = benchfw::bench(label, 2, samples, || {
            match compress_remote(&addr, 1, 16, &data).expect("rpc") {
                Msg::CompressReply { .. } => {}
                other => panic!("unexpected {other:?}"),
            }
        });
        t.row(vec![
            st.name.clone(),
            benchfw::fmt_duration(st.median()),
            benchfw::fmt_duration(st.mad()),
        ]);
        records.push(BenchRecord::from_stats(&st, d, 16));
    }
    t.print();
    println!("service metrics: {}", service.metrics.summary());
    service.shutdown();

    let json = write_bench_json(&repo_root.join("BENCH_pipeline.json"), &records)
        .expect("write BENCH_pipeline.json");
    println!("wrote {} records to {}", records.len(), json.display());
}
