//! `cargo bench --bench bench_pipeline` — system-level numbers:
//!
//! * Appendix C (Fig. 4): sort + quantize timings (incl. the PJRT-executed
//!   Pallas `sq` artifact when `make artifacts` has run);
//! * §7 headline: 1M optimal / 133M near-optimal timings;
//! * coordinator micro-benches: codec, batcher, end-to-end service RPC.

use std::time::Duration;

use quiver::benchfw::{self, Table};
use quiver::coordinator::protocol::Msg;
use quiver::coordinator::router::{Router, RouterConfig};
use quiver::coordinator::service::{compress_remote, Service, ServiceConfig};
use quiver::dist::Dist;
use quiver::figures::{self, FigOpts};
use quiver::sq;

fn main() {
    let out = std::path::PathBuf::from("results");
    let opts = FigOpts::default();

    for id in ["4", "headline"] {
        for t in figures::run(id, &opts).expect("figure") {
            t.print();
            let p = t.save_csv(&out).expect("csv");
            println!("saved {}", p.display());
        }
    }

    // --- Coordinator micro-benches. ---
    let mut t = Table::new("coordinator micro-benches", &["op", "median", "spread"]);
    // Codec: pack/unpack a 1M-coordinate gradient at 4 bits.
    let qs: Vec<f64> = (0..16).map(|i| i as f64).collect();
    let idx: Vec<u32> = (0..1 << 20).map(|i| (i % 16) as u32).collect();
    let st = benchfw::bench("encode 1M@4b", 2, 10, || sq::encode(&idx, &qs));
    t.row(vec![st.name.clone(), benchfw::fmt_duration(st.median()), benchfw::fmt_duration(st.mad())]);
    let packed = sq::encode(&idx, &qs);
    let st = benchfw::bench("decode 1M@4b", 2, 10, || sq::decode(&packed));
    t.row(vec![st.name.clone(), benchfw::fmt_duration(st.median()), benchfw::fmt_duration(st.mad())]);
    // Frame roundtrip.
    let msg = Msg::CompressRequest {
        request_id: 1,
        s: 16,
        data: vec![0.5f32; 1 << 16],
    };
    let st = benchfw::bench("frame 64K req", 2, 20, || {
        let f = msg.to_frame();
        Msg::from_body(&f[4..]).unwrap()
    });
    t.row(vec![st.name.clone(), benchfw::fmt_duration(st.median()), benchfw::fmt_duration(st.mad())]);
    t.print();

    // --- End-to-end service RPC latency (loopback). ---
    let service = Service::start(ServiceConfig {
        threads: 2,
        queue_capacity: 64,
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        router: Router::new(RouterConfig { exact_max_d: 1 << 14, hist_m: 400, seed: 3 }),
        ..Default::default()
    })
    .expect("service");
    let addr = service.addr().to_string();
    let mut t = Table::new("service RPC (loopback)", &["request", "median", "spread"]);
    for (label, d) in [("8K exact", 8_192usize), ("256K hist", 262_144)] {
        let data: Vec<f32> = Dist::LogNormal { mu: 0.0, sigma: 1.0 }
            .sample_vec(d, 7)
            .into_iter()
            .map(|x| x as f32)
            .collect();
        let st = benchfw::bench(label, 2, 10, || {
            match compress_remote(&addr, 1, 16, &data).expect("rpc") {
                Msg::CompressReply { .. } => {}
                other => panic!("unexpected {other:?}"),
            }
        });
        t.row(vec![
            st.name.clone(),
            benchfw::fmt_duration(st.median()),
            benchfw::fmt_duration(st.mad()),
        ]);
    }
    t.print();
    println!("service metrics: {}", service.metrics.summary());
    service.shutdown();
}
