# Convenience targets. `make artifacts` is what the Rust runtime docs and
# error hints refer to: it AOT-lowers the JAX/Pallas graphs to HLO text +
# manifest + golden dumps under rust/artifacts/ (requires jax; see
# python/compile/aot.py).

.PHONY: artifacts build test bench bench-smoke bench-serve chaos lint-contract sanitize clean

artifacts:
	cd python/compile && python3 aot.py --out ../../rust/artifacts

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

bench:
	cd rust && cargo bench --bench bench_solvers && cargo bench --bench bench_approx && cargo bench --bench bench_pipeline && cargo bench --bench bench_ingest

# Reduced-size run of the JSON-emitting bench binaries (seconds, not
# minutes) — what the non-gating CI perf-smoke job executes. Leaves
# BENCH_solvers.json / BENCH_pipeline.json (+ shard/stream) and
# BENCH_ingest.json at the repo root.
bench-smoke:
	cd rust && QUIVER_MAX_POW=13 cargo bench --bench bench_solvers
	cd rust && QUIVER_SMOKE=1 cargo bench --bench bench_pipeline
	cd rust && QUIVER_SMOKE=1 cargo bench --bench bench_ingest

# Seconds-long smoke of the serving front-end load generator (threads vs
# epoll at small connection counts) — what the CI perf-smoke job runs.
# Leaves BENCH_serve.json at the repo root. The full sweep (64/512/4096
# connections, acceptance asserts) is `cd rust && cargo bench --bench
# bench_serve` after `ulimit -n 32768` — see EXPERIMENTS.md.
bench-serve:
	cd rust && QUIVER_SMOKE=1 cargo bench --bench bench_serve

# Gating fault-injection chaos suite: every faultnet::FaultAction driven
# against a live shard fleet through the deterministic fault proxy,
# asserting bitwise-identical recovery or a clean typed error before the
# deadline (DESIGN.md determinism rule 7).
chaos:
	cd rust && cargo test -q --test fault_injection

# Gating determinism-contract lint (rules C1-C6; DESIGN.md "Enforcement").
# Runs from the workspace root so `-p contract-lint` resolves; scans
# rust/src and cross-checks the committed waiver inventory at
# tools/contract-lint/waivers.txt. To record a new `// contract-allow`
# waiver, run `cargo run -p contract-lint -- --write-waivers rust/src`
# and commit the diff.
lint-contract:
	cargo run -p contract-lint -- --check rust/src

# Nightly-toolchain sanitizer lane (non-gating in CI): Miri interprets
# the par::pool unit tests — the one `unsafe` transmute in the tree,
# allowlisted under lint rule C4 — then ThreadSanitizer runs the pool
# and batcher/scheduler tests. Needs `rustup toolchain install nightly
# --component miri,rust-src`.
sanitize:
	cd rust && cargo +nightly miri test par::pool::
	cd rust && RUSTFLAGS="-Zsanitizer=thread" cargo +nightly test \
		-Zbuild-std --target x86_64-unknown-linux-gnu \
		-- par::pool:: coordinator::batcher::

clean:
	cd rust && cargo clean
	rm -rf rust/artifacts results rust/results
