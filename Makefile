# Convenience targets. `make artifacts` is what the Rust runtime docs and
# error hints refer to: it AOT-lowers the JAX/Pallas graphs to HLO text +
# manifest + golden dumps under rust/artifacts/ (requires jax; see
# python/compile/aot.py).

.PHONY: artifacts build test bench bench-smoke clean

artifacts:
	cd python/compile && python3 aot.py --out ../../rust/artifacts

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

bench:
	cd rust && cargo bench --bench bench_solvers && cargo bench --bench bench_approx && cargo bench --bench bench_pipeline

# Reduced-size run of both JSON-emitting bench binaries (seconds, not
# minutes) — what the non-gating CI perf-smoke job executes. Leaves
# BENCH_solvers.json / BENCH_pipeline.json at the repo root.
bench-smoke:
	cd rust && QUIVER_MAX_POW=13 cargo bench --bench bench_solvers
	cd rust && QUIVER_SMOKE=1 cargo bench --bench bench_pipeline

clean:
	cd rust && cargo clean
	rm -rf rust/artifacts results rust/results
