#!/usr/bin/env python3
"""Compare two directories of BENCH_*.json records and emit a Markdown report.

Usage: perf_compare.py BASELINE_DIR CURRENT_DIR [--threshold PCT]

Each BENCH_*.json is a flat array of
``{name, d, s, median_ns, mad_ns, elems_per_s}`` records (see
``rust/src/benchfw``). Records are matched by ``(file, name, d, s)`` —
EXPERIMENTS.md's rule: only compare records whose name *and* shape match.
The report flags regressions/improvements beyond the threshold (default
15%, the documented noise floor for shared runners) and is written to
stdout (the CI job pipes it into $GITHUB_STEP_SUMMARY). Purely
informational: the exit code is always 0 — perf-smoke stays non-gating.
"""

import argparse
import json
import pathlib
import sys


def load(dirpath: pathlib.Path):
    records = {}
    for f in sorted(dirpath.glob("BENCH_*.json")):
        try:
            data = json.loads(f.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"<!-- skipping {f.name}: {e} -->")
            continue
        for r in data:
            key = (f.name, r.get("name"), r.get("d"), r.get("s"))
            records[key] = r
    return records


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", type=pathlib.Path)
    ap.add_argument("current", type=pathlib.Path)
    ap.add_argument("--threshold", type=float, default=15.0,
                    help="percent change considered signal (default 15)")
    args = ap.parse_args()

    base = load(args.baseline)
    cur = load(args.current)
    if not base:
        print("### Perf comparison\n\nNo baseline BENCH_*.json found "
              "(first run, or the previous run uploaded no artifacts) — "
              "nothing to compare.")
        return 0
    if not cur:
        print("### Perf comparison\n\nNo current BENCH_*.json found — "
              "did the bench step fail?")
        return 0

    regressions, improvements, stable = [], [], 0
    rows = []
    for key in sorted(set(base) & set(cur)):
        b, c = base[key], cur[key]
        b_ns, c_ns = b.get("median_ns"), c.get("median_ns")
        if not b_ns or not c_ns:
            continue
        pct = (c_ns - b_ns) / b_ns * 100.0
        if pct >= args.threshold:
            regressions.append((key, b_ns, c_ns, pct))
        elif pct <= -args.threshold:
            improvements.append((key, b_ns, c_ns, pct))
        else:
            stable += 1
        rows.append((key, b_ns, c_ns, pct))

    only_base = sorted(set(base) - set(cur))
    only_cur = sorted(set(cur) - set(base))

    print("### Perf comparison vs previous run (non-gating)\n")
    print(f"{len(rows)} matched records · {stable} within ±{args.threshold:.0f}% · "
          f"{len(regressions)} slower · {len(improvements)} faster · "
          f"{len(only_cur)} new · {len(only_base)} removed\n")
    print(f"Timings from shared runners are noisy — treat ≤ ~{args.threshold:.0f}% "
          "as noise and only chase steps that persist across commits "
          "(see EXPERIMENTS.md).\n")

    def table(title, items):
        if not items:
            return
        print(f"#### {title}\n")
        print("| file | benchmark | baseline | current | Δ |")
        print("|---|---|---:|---:|---:|")
        for (fname, name, _d, _s), b_ns, c_ns, pct in items:
            print(f"| {fname} | {name} | {b_ns / 1e6:.3f} ms | "
                  f"{c_ns / 1e6:.3f} ms | {pct:+.1f}% |")
        print()

    table(f"Slower by ≥ {args.threshold:.0f}%", regressions)
    table(f"Faster by ≥ {args.threshold:.0f}%", improvements)
    if only_cur:
        names = ", ".join(f"`{n}`" for (_f, n, _d, _s) in only_cur[:20])
        print(f"New benchmarks: {names}\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
