#!/usr/bin/env python3
"""Compare BENCH_*.json records across runs and emit a Markdown report.

Usage: perf_compare.py BASELINE_DIR CURRENT_DIR [--threshold PCT]

``BASELINE_DIR`` is either a flat directory of BENCH_*.json files (one
prior run — the original two-way compare) or a directory of *per-run
subdirectories*, each holding that run's BENCH_*.json files, named so
lexicographic order is chronological (the CI perf-smoke job downloads up
to the last six runs as ``run-NN-<run_id>/``). With a history the newest
run is the regression baseline and an additional trend table tracks each
benchmark's median across the whole window, oldest to current.

Each BENCH_*.json is a flat array of
``{name, d, s, median_ns, mad_ns, elems_per_s}`` records (see
``rust/src/benchfw``). Records are matched by ``(file, name, d, s)`` —
EXPERIMENTS.md's rule: only compare records whose name *and* shape match.
The report flags regressions/improvements beyond the threshold (default
15%, the documented noise floor for shared runners) and is written to
stdout (the CI job pipes it into $GITHUB_STEP_SUMMARY). Purely
informational: the exit code is always 0 — perf-smoke stays non-gating.
"""

import argparse
import json
import pathlib
import sys


def load(dirpath: pathlib.Path):
    records = {}
    for f in sorted(dirpath.glob("BENCH_*.json")):
        try:
            data = json.loads(f.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"<!-- skipping {f.name}: {e} -->")
            continue
        if not isinstance(data, list):
            print(f"<!-- skipping {f.name}: not a record array -->")
            continue
        for r in data:
            if not isinstance(r, dict):
                continue
            key = (f.name, r.get("name"), r.get("d"), r.get("s"))
            records[key] = r
    return records


def history_runs(dirpath: pathlib.Path):
    """Per-run subdirectories of ``dirpath`` holding BENCH_*.json records,
    oldest to newest (lexicographic subdirectory order). Empty when
    ``dirpath`` is a plain single-run directory (or missing)."""
    if not dirpath.is_dir():
        return []
    runs = []
    for sub in sorted(p for p in dirpath.iterdir() if p.is_dir()):
        recs = load(sub)
        if recs:
            runs.append((sub.name, recs))
    return runs


def trend_table(runs, cur, max_rows=40):
    """Markdown trend of median_ns across the history window + current."""
    print(f"#### Trend across the last {len(runs)} runs (oldest → newest → current)\n")
    keys = sorted(cur)
    print("| file | benchmark | " + " | ".join(n for n, _ in runs) + " | current | Δ window |")
    print("|---|---|" + "---:|" * (len(runs) + 2))
    shown = 0
    for key in keys:
        if shown >= max_rows:
            break
        cells, first_ns = [], None
        for _, recs in runs:
            ns = recs.get(key, {}).get("median_ns")
            cells.append(f"{ns / 1e6:.3f}" if ns else "–")
            if first_ns is None and ns:
                first_ns = ns
        c_ns = cur[key].get("median_ns")
        if not c_ns:
            continue
        delta = f"{(c_ns - first_ns) / first_ns * 100.0:+.1f}%" if first_ns else "new"
        fname, name, _d, _s = key
        print(f"| {fname} | {name} | " + " | ".join(cells) + f" | {c_ns / 1e6:.3f} | {delta} |")
        shown += 1
    dropped = len(keys) - shown
    note = f" ({dropped} further records elided)" if dropped > 0 else ""
    print(f"\nCells are medians in ms; Δ window is current vs the oldest run "
          f"carrying the record{note}.\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", type=pathlib.Path)
    ap.add_argument("current", type=pathlib.Path)
    ap.add_argument("--threshold", type=float, default=15.0,
                    help="percent change considered signal (default 15)")
    args = ap.parse_args()

    runs = history_runs(args.baseline)
    # With a history of prior runs, the newest is the regression baseline.
    base = runs[-1][1] if runs else load(args.baseline)
    cur = load(args.current)
    if not base:
        print("### Perf comparison\n\nNo baseline BENCH_*.json found "
              "(first run, or the previous run uploaded no artifacts) — "
              "nothing to compare.")
        return 0
    if not cur:
        print("### Perf comparison\n\nNo current BENCH_*.json found — "
              "did the bench step fail?")
        return 0

    regressions, improvements, stable = [], [], 0
    rows = []
    for key in sorted(set(base) & set(cur)):
        b, c = base[key], cur[key]
        b_ns, c_ns = b.get("median_ns"), c.get("median_ns")
        if not b_ns or not c_ns:
            continue
        pct = (c_ns - b_ns) / b_ns * 100.0
        if pct >= args.threshold:
            regressions.append((key, b_ns, c_ns, pct))
        elif pct <= -args.threshold:
            improvements.append((key, b_ns, c_ns, pct))
        else:
            stable += 1
        rows.append((key, b_ns, c_ns, pct))

    only_base = sorted(set(base) - set(cur))
    only_cur = sorted(set(cur) - set(base))

    print("### Perf comparison vs previous run (non-gating)\n")
    print(f"{len(rows)} matched records · {stable} within ±{args.threshold:.0f}% · "
          f"{len(regressions)} slower · {len(improvements)} faster · "
          f"{len(only_cur)} new · {len(only_base)} removed\n")
    print(f"Timings from shared runners are noisy — treat ≤ ~{args.threshold:.0f}% "
          "as noise and only chase steps that persist across commits "
          "(see EXPERIMENTS.md).\n")

    def table(title, items):
        if not items:
            return
        print(f"#### {title}\n")
        print("| file | benchmark | baseline | current | Δ |")
        print("|---|---|---:|---:|---:|")
        for (fname, name, _d, _s), b_ns, c_ns, pct in items:
            print(f"| {fname} | {name} | {b_ns / 1e6:.3f} ms | "
                  f"{c_ns / 1e6:.3f} ms | {pct:+.1f}% |")
        print()

    table(f"Slower by ≥ {args.threshold:.0f}%", regressions)
    table(f"Faster by ≥ {args.threshold:.0f}%", improvements)
    if only_cur:
        names = ", ".join(f"`{n}`" for (_f, n, _d, _s) in only_cur[:20])
        print(f"New benchmarks: {names}\n")
    if len(runs) >= 2:
        trend_table(runs, cur)
    return 0


if __name__ == "__main__":
    sys.exit(main())
