//! The determinism/safety contract as machine-checkable rules.
//!
//! `quiver`'s bitwise-determinism contract (DESIGN.md rules 1–7) is
//! enforced dynamically by the invariance test suites; this crate is the
//! static half: a dependency-free lexer plus a line-based syntax walk over
//! `rust/src/**` that rejects contract-violating *code shapes* at CI time.
//! Six rules, stable IDs:
//!
//! - **C1** — RNG roots (`Xoshiro256pp::new` / `seed_from_u64` /
//!   `from_seed`) may appear only in allow-listed derivation sites
//!   ([`C1_ALLOWED`]); everywhere else must derive via
//!   `Xoshiro256pp::stream(base, idx)` so seeding stays a pure function of
//!   config seeds (DESIGN.md rule 2).
//! - **C2** — no `HashMap`/`HashSet` in the numeric modules or in
//!   `coordinator`: hash iteration order is nondeterministic per process,
//!   so it can leak into results and wire output. Use `BTreeMap` /
//!   `BTreeSet` / `Vec` (DESIGN.md rules 3–5).
//! - **C3** — no `Instant::now` / `SystemTime` / ad-hoc thread spawns in
//!   the numeric modules; wall-clock time and threads belong to
//!   `coordinator` and the `par` executor core ([`C3_THREAD_EXEMPT`]).
//! - **C4** — every `unsafe` must carry a `// SAFETY:` comment and a
//!   matching entry in the checked-in allowlist
//!   (`tools/contract-lint/unsafe_allowlist.txt`); stale allowlist entries
//!   are errors too, so the audit surface never drifts.
//! - **C5** — in the wire-decoding files ([`C5_FILES`]) every `as usize`
//!   cast and `with_capacity` call must sit next to a visible bounds check
//!   ([`C5_GUARDS`], within [`C5_BEFORE`]/[`C5_AFTER`] lines): a
//!   wire-supplied length used raw is an allocation-bomb / wraparound bug.
//!   Capacities that cannot be wire-controlled are exempt: function
//!   *definitions* (`fn with_capacity(…)`), integer-literal capacities,
//!   and capacities derived from `.len()` of data already in memory.
//! - **C6** — no unbounded blocking I/O in `coordinator`: a raw
//!   `TcpStream::connect(` (no deadline — use
//!   `fault::connect`/`connect_timeout`) is always an error, and every
//!   `BufReader::new(` over a socket must sit near a visible deadline
//!   guard ([`C6_GUARDS`], within [`C6_BEFORE`]/[`C6_AFTER`] lines) — a
//!   reader on an undeadlined socket can park a thread forever on one
//!   wedged peer (DESIGN.md rule 7).
//!
//! Any rule can be waived per site with `// contract-allow(Cn): reason`
//! (same line or the line above). Waivers are not free: the linter records
//! every one into a committed inventory (`tools/contract-lint/waivers.txt`)
//! and `--check` fails when tree and inventory disagree — so adding a
//! waiver is a reviewable diff, and a waiver that stops matching anything
//! is an error, not silence.
//!
//! The lexer strips comments, strings and char literals (so tokens inside
//! them never match) and tracks `#[cfg(test)]` / `#[test]` regions by brace
//! depth: C1/C2/C3/C5/C6 skip test code (tests seed RNGs and build
//! fixtures freely), C4 applies everywhere. This is a *lexical* checker by design:
//! it cannot resolve aliases (`use Xoshiro256pp as R`) or dataflow, and
//! trades those false negatives for zero dependencies and sub-second runs.

use std::collections::BTreeSet;
use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Rule identifiers, stable across releases (waiver comments, the
/// inventory file and CI logs all refer to these).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Rule {
    /// RNG roots only in allow-listed derivation sites.
    C1,
    /// No hash-ordered containers in numeric modules or `coordinator`.
    C2,
    /// No wall-clock / ad-hoc threads in numeric modules.
    C3,
    /// `unsafe` requires a `// SAFETY:` comment + allowlist entry.
    C4,
    /// Wire-length casts/allocations require a nearby bounds check.
    C5,
    /// No undeadlined blocking sockets in `coordinator`.
    C6,
}

impl Rule {
    /// All rules, in ID order.
    pub const ALL: [Rule; 6] = [Rule::C1, Rule::C2, Rule::C3, Rule::C4, Rule::C5, Rule::C6];

    /// The stable ID string (`"C1"` … `"C6"`).
    pub fn id(self) -> &'static str {
        match self {
            Rule::C1 => "C1",
            Rule::C2 => "C2",
            Rule::C3 => "C3",
            Rule::C4 => "C4",
            Rule::C5 => "C5",
            Rule::C6 => "C6",
        }
    }

    /// Parse an ID string (as written in waiver comments / the inventory).
    pub fn parse(s: &str) -> Option<Rule> {
        match s.trim() {
            "C1" => Some(Rule::C1),
            "C2" => Some(Rule::C2),
            "C3" => Some(Rule::C3),
            "C4" => Some(Rule::C4),
            "C5" => Some(Rule::C5),
            "C6" => Some(Rule::C6),
            _ => None,
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

/// One violation (or audit error) at a source location. `line` is 1-based;
/// 0 means "whole file / inventory" (stale-entry errors).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// The rule that fired.
    pub rule: Rule,
    /// Path relative to the scan root, `/`-separated.
    pub path: String,
    /// 1-based line, 0 for file-level errors.
    pub line: usize,
    /// Human-readable description.
    pub message: String,
}

/// A used `// contract-allow` escape hatch, as recorded in the inventory.
/// Identity is `(rule, path, reason)` — line numbers are deliberately not
/// part of it, so unrelated edits above a waiver don't churn the file.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Waiver {
    /// The rule being waived.
    pub rule: Rule,
    /// Path relative to the scan root, `/`-separated.
    pub path: String,
    /// The justification text after `contract-allow(Cn):`.
    pub reason: String,
}

/// Result of a full-tree lint.
#[derive(Debug, Default)]
pub struct Report {
    /// Number of `.rs` files scanned.
    pub files: usize,
    /// Violations plus audit errors (unused waivers, stale allowlist
    /// entries), in path/line order.
    pub findings: Vec<Finding>,
    /// Every waiver that suppressed at least one finding, sorted, deduped.
    pub waivers: Vec<Waiver>,
}

/// Linter configuration: where to scan and the C4 unsafe allowlist
/// (`(relative path, line fragment)` pairs).
#[derive(Debug, Clone)]
pub struct Config {
    /// Scan root (e.g. `rust/src`); every `.rs` file under it is linted.
    pub root: PathBuf,
    /// C4 allowlist: an `unsafe` line is accepted when some entry's path
    /// equals the file and its fragment appears in the line's code.
    pub allowlist: Vec<(String, String)>,
}

// ---------------------------------------------------------------------------
// Rule tables. These are the contract's ground truth: reviewed in this
// file, referenced from DESIGN.md §Enforcement.
// ---------------------------------------------------------------------------

/// Modules whose outputs are numeric results (bitwise-compared by the
/// invariance suites). Rules C2/C3 cover these.
pub const NUMERIC_MODULES: &[&str] = &["avq", "baselines", "sq", "stream", "dist", "par"];

/// C1 token patterns: calls that *root* a generator instead of deriving it.
pub const C1_ROOTS: &[&str] =
    &["Xoshiro256pp::new(", "Xoshiro256pp::seed_from_u64(", "Xoshiro256pp::from_seed("];

/// C1 allow-listed derivation sites (path-prefix match, relative to the
/// scan root). Each is a place where rooting a generator from a config
/// seed is the *design*, not a leak:
///
/// - `util/rng.rs` — defines the generator and the `stream`/`fork`
///   derivation itself.
/// - `dist.rs` — dataset sampling roots; the seed is an explicit argument.
/// - `main.rs` — CLI entry points root from the parsed config.
/// - `figures/` — figure harnesses use fixed, documented seeds.
/// - `testutil/` — test-data generation helpers.
/// - `avq/histogram.rs` — `solve_hist` roots from `HistConfig.seed`, then
///   derives per-chunk streams (DESIGN.md rule 2).
/// - `stream/mod.rs` — `stream_base`: one fixed draw mapping a stream seed
///   to its round base.
/// - `coordinator/tasks.rs` — synthetic-task teacher/stream roots.
/// - `coordinator/worker.rs` — per-worker root from `WorkerConfig.seed`.
/// - `coordinator/shard.rs` — shard-local histogram roots from the config
///   seed (bit-equal to the unsharded root by construction).
/// - `coordinator/service.rs` — per-solver-thread and per-stream roots
///   from the service seed.
pub const C1_ALLOWED: &[&str] = &[
    "util/rng.rs",
    "dist.rs",
    "main.rs",
    "figures/",
    "testutil/",
    "avq/histogram.rs",
    "stream/mod.rs",
    "coordinator/tasks.rs",
    "coordinator/worker.rs",
    "coordinator/shard.rs",
    "coordinator/service.rs",
];

/// C3 wall-clock patterns.
pub const C3_TIME: &[&str] = &["Instant::now(", "SystemTime"];

/// C3 thread patterns.
pub const C3_THREADS: &[&str] = &["thread::spawn", "thread::scope", "thread::Builder"];

/// Files exempt from C3's *thread* patterns: the executor substrate itself
/// (`par::pool` owns the worker threads; `par/mod.rs` hosts the scoped
/// reference backend). Wall-clock patterns still apply to them.
pub const C3_THREAD_EXEMPT: &[&str] = &["par/mod.rs", "par/pool.rs"];

/// Files C5 covers: everything that decodes attacker-controlled bytes.
pub const C5_FILES: &[&str] = &[
    "coordinator/protocol.rs",
    "coordinator/codec.rs",
    "coordinator/eventloop.rs",
    "coordinator/faultnet.rs",
    "coordinator/ingest.rs",
    "coordinator/shard.rs",
    "sq/codec.rs",
];

/// Tokens that count as a visible bounds check for C5. Substring match
/// against nearby *code* (comments never count).
pub const C5_GUARDS: &[&str] = &[
    "checked_mul",
    "checked_add",
    "checked_sub",
    "try_from(",
    "ensure!",
    "assert!",
    "assert_eq!",
    "bail!",
    ".remaining()",
    ".min(",
    "MAX_",
];

/// C5 guard window: lines searched above a flagged cast/allocation.
pub const C5_BEFORE: usize = 6;
/// C5 guard window: lines searched below a flagged cast/allocation.
pub const C5_AFTER: usize = 3;

/// C6 banned pattern: a connect with no deadline. (Deliberately does not
/// match `TcpStream::connect_timeout(`, the sanctioned form.)
pub const C6_CONNECT: &str = "TcpStream::connect(";

/// C6 reader patterns: blocking readers built over a socket.
pub const C6_READERS: &[&str] = &["BufReader::new("];

/// Tokens that count as a visible socket deadline for C6. Substring match
/// against nearby *code* (comments never count). `fault::connect` also
/// matches `fault::connect_retry`; both return deadlined sockets.
pub const C6_GUARDS: &[&str] =
    &["set_read_timeout", "set_write_timeout", "io_timeouts", "fault::connect"];

/// C6 guard window: lines searched above a flagged reader. Wider than
/// C5's — the deadline guard legitimately sits at the top of a handler,
/// several declarations above the reader it covers.
pub const C6_BEFORE: usize = 10;
/// C6 guard window: lines searched below a flagged reader.
pub const C6_AFTER: usize = 3;

// ---------------------------------------------------------------------------
// Lexer
// ---------------------------------------------------------------------------

/// One lexed source line: `code` has comments and string/char-literal
/// contents blanked to spaces (same length as the input), `comment` holds
/// the text of any `//` comment on the line, and `in_test` marks lines
/// inside `#[cfg(test)]` / `#[test]` regions.
#[derive(Debug, Clone, Default)]
pub struct SrcLine {
    /// Code text with non-code bytes blanked.
    pub code: String,
    /// Line-comment text (empty when the line has none).
    pub comment: String,
    /// True inside test modules/functions (tracked by brace depth).
    pub in_test: bool,
}

#[derive(PartialEq)]
enum LexState {
    Normal,
    LineComment,
    Block(u32),
    Cooked,
    Raw(usize),
}

fn starts_with_at(cs: &[char], i: usize, pat: &str) -> bool {
    pat.chars().enumerate().all(|(k, pc)| cs.get(i + k) == Some(&pc))
}

/// Lex a file into [`SrcLine`]s (1 input line = 1 output line).
pub fn lex(source: &str) -> Vec<SrcLine> {
    let cs: Vec<char> = source.chars().collect();
    let n = cs.len();
    let mut lines: Vec<SrcLine> = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut st = LexState::Normal;
    let mut depth: usize = 0;
    let mut test_stack: Vec<usize> = Vec::new();
    let mut pending_test = false;
    // `was_test`: whether the current line *started* inside a test region
    // (or right after a test attribute) — so a region closing mid-line
    // still flags the line, and `#[test] fn f() {` flags from the brace on.
    let mut was_test = false;
    let mut i = 0usize;

    while i < n {
        let c = cs[i];
        if c == '\n' {
            let in_test = was_test || !test_stack.is_empty();
            lines.push(SrcLine {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
                in_test,
            });
            was_test = !test_stack.is_empty() || pending_test;
            if st == LexState::LineComment {
                st = LexState::Normal;
            }
            i += 1;
            continue;
        }
        match st {
            LexState::Normal => {
                match c {
                    '/' if cs.get(i + 1) == Some(&'/') => {
                        comment.push_str(&collect_to_eol(&cs, i));
                        code.push(' ');
                        code.push(' ');
                        st = LexState::LineComment;
                        i += 2;
                    }
                    '/' if cs.get(i + 1) == Some(&'*') => {
                        code.push(' ');
                        code.push(' ');
                        st = LexState::Block(1);
                        i += 2;
                    }
                    '"' => {
                        code.push('"');
                        st = LexState::Cooked;
                        i += 1;
                    }
                    'r' | 'b' if !prev_is_ident(&cs, i) => {
                        let (consumed, hashes, cooked) = string_prefix(&cs, i);
                        if consumed > 0 {
                            for k in 0..consumed {
                                code.push(cs[i + k]);
                            }
                            st = if cooked { LexState::Cooked } else { LexState::Raw(hashes) };
                            i += consumed;
                        } else {
                            code.push(c);
                            i += 1;
                        }
                    }
                    '\'' => {
                        // Char literal vs lifetime: a backslash or a
                        // closing quote two chars on means literal.
                        if cs.get(i + 1) == Some(&'\\') {
                            code.push('\'');
                            code.push(' ');
                            i += 2;
                            while i < n && cs[i] != '\'' && cs[i] != '\n' {
                                code.push(' ');
                                i += 1;
                            }
                            if i < n && cs[i] == '\'' {
                                code.push('\'');
                                i += 1;
                            }
                        } else if cs.get(i + 2) == Some(&'\'') {
                            code.push('\'');
                            code.push(' ');
                            code.push('\'');
                            i += 3;
                        } else {
                            code.push('\'');
                            i += 1;
                        }
                    }
                    '#' => {
                        if starts_with_at(&cs, i, "#[cfg(test)]")
                            || starts_with_at(&cs, i, "#[test]")
                        {
                            pending_test = true;
                        }
                        code.push('#');
                        i += 1;
                    }
                    '{' => {
                        depth += 1;
                        if pending_test {
                            test_stack.push(depth);
                            pending_test = false;
                        }
                        code.push('{');
                        i += 1;
                    }
                    '}' => {
                        if test_stack.last() == Some(&depth) {
                            test_stack.pop();
                        }
                        depth = depth.saturating_sub(1);
                        code.push('}');
                        i += 1;
                    }
                    _ => {
                        code.push(c);
                        i += 1;
                    }
                }
            }
            LexState::LineComment => {
                // Comment text was captured wholesale on entry.
                code.push(' ');
                i += 1;
            }
            LexState::Block(d) => {
                if c == '*' && cs.get(i + 1) == Some(&'/') {
                    code.push(' ');
                    code.push(' ');
                    st = if d == 1 { LexState::Normal } else { LexState::Block(d - 1) };
                    i += 2;
                } else if c == '/' && cs.get(i + 1) == Some(&'*') {
                    code.push(' ');
                    code.push(' ');
                    st = LexState::Block(d + 1);
                    i += 2;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            LexState::Cooked => {
                if c == '\\' {
                    if cs.get(i + 1) == Some(&'\n') {
                        code.push(' ');
                        i += 1; // newline handled by the main loop
                    } else {
                        code.push(' ');
                        code.push(' ');
                        i += 2;
                    }
                } else if c == '"' {
                    code.push('"');
                    st = LexState::Normal;
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            LexState::Raw(h) => {
                if c == '"' && (0..h).all(|k| cs.get(i + 1 + k) == Some(&'#')) {
                    code.push('"');
                    for _ in 0..h {
                        code.push('#');
                    }
                    st = LexState::Normal;
                    i += 1 + h;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        let in_test = was_test || !test_stack.is_empty();
        lines.push(SrcLine { code, comment, in_test });
    }
    lines
}

fn collect_to_eol(cs: &[char], i: usize) -> String {
    cs[i..].iter().take_while(|&&c| c != '\n').collect()
}

fn prev_is_ident(cs: &[char], i: usize) -> bool {
    i > 0 && (cs[i - 1].is_alphanumeric() || cs[i - 1] == '_')
}

/// Detect a string-literal prefix at `i` (`b"`, `r"`, `r#"`, `br#"` …).
/// Returns `(chars consumed through the opening quote, hash count,
/// is_cooked)`; consumed 0 means "not a string prefix".
fn string_prefix(cs: &[char], i: usize) -> (usize, usize, bool) {
    let mut j = i;
    if cs.get(j) == Some(&'b') {
        j += 1;
        if cs.get(j) == Some(&'"') {
            return (j + 1 - i, 0, true); // b"..." — cooked byte string
        }
    }
    if cs.get(j) == Some(&'r') {
        j += 1;
        let mut hashes = 0usize;
        while cs.get(j) == Some(&'#') {
            hashes += 1;
            j += 1;
        }
        if cs.get(j) == Some(&'"') {
            return (j + 1 - i, hashes, false); // r"…", r#"…"#, br#"…"#
        }
    }
    (0, 0, false)
}

// ---------------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------------

/// Whole-word substring search (no identifier chars adjacent to the hit).
fn word_hit(code: &str, pat: &str) -> bool {
    let bytes = code.as_bytes();
    let mut start = 0usize;
    while let Some(pos) = code[start..].find(pat) {
        let p = start + pos;
        let before_ok =
            p == 0 || !(bytes[p - 1].is_ascii_alphanumeric() || bytes[p - 1] == b'_');
        let end = p + pat.len();
        let after_ok =
            end >= bytes.len() || !(bytes[end].is_ascii_alphanumeric() || bytes[end] == b'_');
        if before_ok && after_ok {
            return true;
        }
        start = p + 1;
    }
    false
}

/// The module a relative path belongs to: its first directory, or the file
/// stem for root-level files (`dist.rs` → `dist`).
fn module_of(rel: &str) -> &str {
    match rel.find('/') {
        Some(k) => &rel[..k],
        None => rel.strip_suffix(".rs").unwrap_or(rel),
    }
}

fn path_allowed(rel: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| rel == *p || rel.starts_with(p))
}

/// The argument of a call whose `(` sits at `open` (matching-paren scan);
/// `None` when the call spans lines (treated as risky).
fn capacity_arg(code: &str, open: usize) -> Option<&str> {
    let bytes = code.as_bytes();
    let mut depth = 0i32;
    for k in open..bytes.len() {
        match bytes[k] {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return Some(code[open + 1..k].trim());
                }
            }
            _ => {}
        }
    }
    None
}

/// True when the line holds a `with_capacity` *call* whose capacity could
/// be wire-controlled. Exempt: definitions (`fn with_capacity(…)`),
/// integer-literal capacities, and capacities derived from `.len()` of
/// data already in memory (an allocation bounded by an existing one).
fn has_risky_capacity(code: &str) -> bool {
    let mut start = 0usize;
    while let Some(pos) = code[start..].find("with_capacity(") {
        let p = start + pos;
        let is_definition = code[..p].contains("fn ");
        let open = p + "with_capacity".len();
        let benign = match capacity_arg(code, open) {
            Some(arg) => {
                !arg.is_empty()
                    && (arg.chars().all(|c| c.is_ascii_digit() || c == '_')
                        || arg.contains(".len()"))
            }
            None => false,
        };
        if !is_definition && !benign {
            return true;
        }
        start = open;
    }
    false
}

/// True when some comment directly above `idx` (through a contiguous run
/// of comment-only/blank lines, same-line included) contains `SAFETY:`.
fn has_safety_comment(lines: &[SrcLine], idx: usize) -> bool {
    if lines[idx].comment.contains("SAFETY:") {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        if !l.code.trim().is_empty() {
            return false;
        }
        if l.comment.contains("SAFETY:") {
            return true;
        }
    }
    false
}

fn parse_waiver(comment: &str) -> Option<(Rule, String)> {
    let k = comment.find("contract-allow(")?;
    let rest = &comment[k + "contract-allow(".len()..];
    let close = rest.find(')')?;
    let rule = Rule::parse(&rest[..close])?;
    let after = rest[close + 1..].trim_start();
    let reason = after.strip_prefix(':').unwrap_or(after).trim().to_string();
    Some((rule, reason))
}

/// Lint one lexed file. `used_allow` collects indices of C4 allowlist
/// entries that matched (for the stale-entry check across the whole tree).
fn lint_file(
    rel: &str,
    lines: &[SrcLine],
    cfg: &Config,
    used_allow: &mut BTreeSet<usize>,
) -> (Vec<Finding>, Vec<Waiver>) {
    let module = module_of(rel);
    let numeric = NUMERIC_MODULES.contains(&module);
    let c2_covered = numeric || module == "coordinator";
    let c5_covered = path_allowed(rel, C5_FILES);
    let c6_covered = module == "coordinator";

    // (line index, rule, message), deduped per (line, rule).
    let mut raw: Vec<(usize, Rule, String)> = Vec::new();
    let mut seen: BTreeSet<(usize, Rule)> = BTreeSet::new();
    let mut push = |raw: &mut Vec<(usize, Rule, String)>,
                    seen: &mut BTreeSet<(usize, Rule)>,
                    idx: usize,
                    rule: Rule,
                    msg: String| {
        if seen.insert((idx, rule)) {
            raw.push((idx, rule, msg));
        }
    };

    for (idx, line) in lines.iter().enumerate() {
        let code = &line.code;

        // C4 applies everywhere, tests included.
        if word_hit(code, "unsafe") {
            if !has_safety_comment(lines, idx) {
                push(
                    &mut raw,
                    &mut seen,
                    idx,
                    Rule::C4,
                    "`unsafe` without a `// SAFETY:` comment".into(),
                );
            }
            let mut listed = false;
            for (k, (path, fragment)) in cfg.allowlist.iter().enumerate() {
                if path == rel && code.contains(fragment.as_str()) {
                    used_allow.insert(k);
                    listed = true;
                }
            }
            if !listed {
                push(
                    &mut raw,
                    &mut seen,
                    idx,
                    Rule::C4,
                    "`unsafe` not covered by tools/contract-lint/unsafe_allowlist.txt".into(),
                );
            }
        }

        if line.in_test {
            continue;
        }

        // C1: RNG roots outside the derivation allowlist.
        if !path_allowed(rel, C1_ALLOWED) {
            for pat in C1_ROOTS {
                if code.contains(pat) {
                    push(
                        &mut raw,
                        &mut seen,
                        idx,
                        Rule::C1,
                        format!(
                            "RNG root `{}` outside allow-listed derivation sites; \
                             derive via Xoshiro256pp::stream(base, idx)",
                            pat.trim_end_matches('(')
                        ),
                    );
                }
            }
        }

        // C2: hash-ordered containers where order can leak out.
        if c2_covered {
            for pat in ["HashMap", "HashSet"] {
                if word_hit(code, pat) {
                    push(
                        &mut raw,
                        &mut seen,
                        idx,
                        Rule::C2,
                        format!(
                            "`{pat}` in `{module}`: iteration order is nondeterministic; \
                             use BTreeMap/BTreeSet or a Vec"
                        ),
                    );
                }
            }
        }

        // C3: wall-clock / ad-hoc threads in numeric modules.
        if numeric {
            for pat in C3_TIME {
                if code.contains(pat) {
                    push(
                        &mut raw,
                        &mut seen,
                        idx,
                        Rule::C3,
                        format!("wall-clock `{}` in numeric module `{module}`", pat.trim_end_matches('(')),
                    );
                }
            }
            if !path_allowed(rel, C3_THREAD_EXEMPT) {
                for pat in C3_THREADS {
                    if code.contains(pat) {
                        push(
                            &mut raw,
                            &mut seen,
                            idx,
                            Rule::C3,
                            format!(
                                "`{pat}` in numeric module `{module}`: threads belong to \
                                 coordinator/par::pool"
                            ),
                        );
                    }
                }
            }
        }

        // C5: raw wire-length casts/allocations without a nearby guard.
        if c5_covered {
            let cast = word_hit(code, "as usize");
            let cap = has_risky_capacity(code);
            if cast || cap {
                let lo = idx.saturating_sub(C5_BEFORE);
                let hi = (idx + C5_AFTER).min(lines.len().saturating_sub(1));
                let guarded = (lo..=hi).any(|j| {
                    !lines[j].in_test
                        && C5_GUARDS.iter().any(|g| lines[j].code.contains(g))
                });
                if !guarded {
                    let what = if cast { "`as usize` cast" } else { "`with_capacity` call" };
                    push(
                        &mut raw,
                        &mut seen,
                        idx,
                        Rule::C5,
                        format!(
                            "{what} on a wire-decoded value with no bounds check within \
                             {C5_BEFORE} lines above / {C5_AFTER} below"
                        ),
                    );
                }
            }
        }

        // C6: undeadlined blocking sockets in the coordinator.
        if c6_covered {
            if code.contains(C6_CONNECT) {
                push(
                    &mut raw,
                    &mut seen,
                    idx,
                    Rule::C6,
                    "`TcpStream::connect` has no deadline; use `fault::connect` \
                     (or `TcpStream::connect_timeout`)"
                        .into(),
                );
            }
            if C6_READERS.iter().any(|p| code.contains(p)) {
                let lo = idx.saturating_sub(C6_BEFORE);
                let hi = (idx + C6_AFTER).min(lines.len().saturating_sub(1));
                let guarded = (lo..=hi).any(|j| {
                    !lines[j].in_test
                        && C6_GUARDS.iter().any(|g| lines[j].code.contains(g))
                });
                if !guarded {
                    push(
                        &mut raw,
                        &mut seen,
                        idx,
                        Rule::C6,
                        format!(
                            "blocking reader on a socket with no visible deadline guard \
                             within {C6_BEFORE} lines above / {C6_AFTER} below"
                        ),
                    );
                }
            }
        }
    }

    // Waivers: `// contract-allow(Cn): reason` suppresses findings of rule
    // Cn on its own line and the line below.
    let mut waiver_sites: Vec<(usize, Rule, String, bool)> = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        if let Some((rule, reason)) = parse_waiver(&line.comment) {
            waiver_sites.push((idx, rule, reason, false));
        }
    }

    let mut findings = Vec::new();
    let mut waivers = Vec::new();
    for (idx, rule, msg) in raw {
        let mut suppressed = false;
        for (widx, wrule, reason, used) in waiver_sites.iter_mut() {
            if *wrule == rule && (*widx == idx || *widx + 1 == idx) {
                *used = true;
                suppressed = true;
                waivers.push(Waiver { rule, path: rel.to_string(), reason: reason.clone() });
            }
        }
        if !suppressed {
            findings.push(Finding { rule, path: rel.to_string(), line: idx + 1, message: msg });
        }
    }
    for (widx, wrule, _, used) in &waiver_sites {
        if !used {
            findings.push(Finding {
                rule: *wrule,
                path: rel.to_string(),
                line: widx + 1,
                message: format!(
                    "unused `contract-allow({wrule})` waiver (suppresses nothing — remove it)"
                ),
            });
        }
    }
    (findings, waivers)
}

// ---------------------------------------------------------------------------
// Tree walk + entry point
// ---------------------------------------------------------------------------

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `cfg.root`.
pub fn run(cfg: &Config) -> io::Result<Report> {
    let mut files = Vec::new();
    collect_rs(&cfg.root, &mut files)?;
    files.sort();

    let mut report = Report::default();
    let mut used_allow: BTreeSet<usize> = BTreeSet::new();
    let mut waiver_set: BTreeSet<Waiver> = BTreeSet::new();

    for path in &files {
        let rel = path
            .strip_prefix(&cfg.root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let source = fs::read_to_string(path)?;
        let lines = lex(&source);
        let (findings, waivers) = lint_file(&rel, &lines, cfg, &mut used_allow);
        report.findings.extend(findings);
        waiver_set.extend(waivers);
        report.files += 1;
    }

    for (k, (path, fragment)) in cfg.allowlist.iter().enumerate() {
        if !used_allow.contains(&k) {
            report.findings.push(Finding {
                rule: Rule::C4,
                path: path.clone(),
                line: 0,
                message: format!(
                    "stale unsafe_allowlist entry (no matching `unsafe` line): `{fragment}`"
                ),
            });
        }
    }

    report.waivers = waiver_set.into_iter().collect();
    Ok(report)
}

// ---------------------------------------------------------------------------
// Allowlist / inventory file formats (tab-separated, `#` comments)
// ---------------------------------------------------------------------------

/// Parse `unsafe_allowlist.txt`: `path<TAB>line fragment` per entry.
pub fn parse_allowlist(text: &str) -> Vec<(String, String)> {
    text.lines()
        .map(str::trim_end)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .filter_map(|l| {
            let (path, fragment) = l.split_once('\t')?;
            Some((path.trim().to_string(), fragment.trim().to_string()))
        })
        .collect()
}

/// Parse `waivers.txt`: `rule<TAB>path<TAB>reason` per entry.
pub fn parse_inventory(text: &str) -> Vec<Waiver> {
    text.lines()
        .map(str::trim_end)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .filter_map(|l| {
            let mut parts = l.splitn(3, '\t');
            let rule = Rule::parse(parts.next()?)?;
            let path = parts.next()?.trim().to_string();
            let reason = parts.next()?.trim().to_string();
            Some(Waiver { rule, path, reason })
        })
        .collect()
}

/// Render a waiver set in `waivers.txt` format (stable order).
pub fn render_inventory(waivers: &[Waiver]) -> String {
    let mut out = String::from(
        "# contract-lint waiver inventory — generated by `contract-lint --write-waivers`.\n\
         # One line per `// contract-allow(Cn): reason` site that suppresses a finding:\n\
         # rule<TAB>path (relative to the scan root)<TAB>reason.\n\
         # `--check` fails when this file and the tree disagree; review diffs here\n\
         # like code.\n",
    );
    let mut sorted: Vec<&Waiver> = waivers.iter().collect();
    sorted.sort();
    for w in sorted {
        out.push_str(&format!("{}\t{}\t{}\n", w.rule, w.path, w.reason));
    }
    out
}
