//! CLI for the determinism/safety contract linter.
//!
//! ```text
//! cargo run -p contract-lint -- --check rust/src          # gate (CI)
//! cargo run -p contract-lint -- --write-waivers rust/src  # refresh inventory
//! ```
//!
//! `--check` exits non-zero on any rule violation, on an unused waiver
//! comment, on a stale unsafe-allowlist entry, or when the waivers found
//! in the tree disagree with the committed inventory
//! (`tools/contract-lint/waivers.txt`). `--write-waivers` regenerates the
//! inventory from the tree so the diff can be reviewed and committed.

use std::fs;
use std::path::PathBuf;
use std::process::ExitCode;

use contract_lint::{
    parse_allowlist, parse_inventory, render_inventory, run, Config, Waiver,
};

fn manifest_file(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(name)
}

fn usage() -> ExitCode {
    eprintln!(
        "usage: contract-lint (--check | --write-waivers) <root> \
         [--waivers FILE] [--unsafe-allowlist FILE]"
    );
    ExitCode::from(2)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut mode: Option<&str> = None;
    let mut root: Option<PathBuf> = None;
    let mut waivers_path = manifest_file("waivers.txt");
    let mut allowlist_path = manifest_file("unsafe_allowlist.txt");

    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--check" => mode = Some("check"),
            "--write-waivers" => mode = Some("write"),
            "--waivers" => match it.next() {
                Some(p) => waivers_path = PathBuf::from(p),
                None => return usage(),
            },
            "--unsafe-allowlist" => match it.next() {
                Some(p) => allowlist_path = PathBuf::from(p),
                None => return usage(),
            },
            other if !other.starts_with('-') && root.is_none() => {
                root = Some(PathBuf::from(other));
            }
            _ => return usage(),
        }
    }
    let (Some(mode), Some(root)) = (mode, root) else {
        return usage();
    };

    let allowlist = match fs::read_to_string(&allowlist_path) {
        Ok(text) => parse_allowlist(&text),
        Err(e) => {
            eprintln!("contract-lint: cannot read {}: {e}", allowlist_path.display());
            return ExitCode::from(2);
        }
    };

    let cfg = Config { root: root.clone(), allowlist };
    let report = match run(&cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("contract-lint: scan of {} failed: {e}", root.display());
            return ExitCode::from(2);
        }
    };

    if mode == "write" {
        let text = render_inventory(&report.waivers);
        if let Err(e) = fs::write(&waivers_path, text) {
            eprintln!("contract-lint: cannot write {}: {e}", waivers_path.display());
            return ExitCode::from(2);
        }
        println!(
            "contract-lint: wrote {} waiver(s) to {}",
            report.waivers.len(),
            waivers_path.display()
        );
        // Violations still fail the write mode, so a forgotten fix cannot
        // hide behind an inventory refresh.
        for f in &report.findings {
            eprintln!("{}/{}:{}: [{}] {}", root.display(), f.path, f.line, f.rule, f.message);
        }
        return if report.findings.is_empty() { ExitCode::SUCCESS } else { ExitCode::FAILURE };
    }

    // --check: violations + inventory drift.
    let mut errors = report.findings.len();
    for f in &report.findings {
        eprintln!("{}/{}:{}: [{}] {}", root.display(), f.path, f.line, f.rule, f.message);
    }

    let inventory: Vec<Waiver> = match fs::read_to_string(&waivers_path) {
        Ok(text) => parse_inventory(&text),
        Err(e) => {
            eprintln!("contract-lint: cannot read {}: {e}", waivers_path.display());
            return ExitCode::from(2);
        }
    };
    for w in &report.waivers {
        if !inventory.contains(w) {
            errors += 1;
            eprintln!(
                "{}: [{}] waiver not recorded in {} — run `cargo run -p contract-lint -- \
                 --write-waivers {}` and commit the diff: {}",
                w.path,
                w.rule,
                waivers_path.display(),
                root.display(),
                w.reason
            );
        }
    }
    for w in &inventory {
        if !report.waivers.contains(w) {
            errors += 1;
            eprintln!(
                "{}: [{}] stale inventory entry in {} (no matching waiver in the tree): {}",
                w.path,
                w.rule,
                waivers_path.display(),
                w.reason
            );
        }
    }

    println!(
        "contract-lint: {} file(s), {} finding(s), {} waiver(s)",
        report.files,
        errors,
        report.waivers.len()
    );
    if errors == 0 {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
