//! Fixture suite for the contract linter: one minimal bad-code snippet per
//! rule ID, each asserted to trip exactly its rule and nothing else, plus
//! the waiver, allowlist and test-exemption paths.

use std::path::PathBuf;

use contract_lint::{run, Config, Report, Rule, Waiver};

fn fixture_root(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("fixtures").join(name)
}

fn lint(name: &str, allowlist: Vec<(String, String)>) -> Report {
    run(&Config { root: fixture_root(name), allowlist })
        .unwrap_or_else(|e| panic!("fixture {name} scan failed: {e}"))
}

#[test]
fn each_rule_fires_on_its_fixture_and_nothing_else() {
    let cases = [
        ("c1", Rule::C1),
        ("c2", Rule::C2),
        ("c3", Rule::C3),
        ("c4", Rule::C4),
        ("c5", Rule::C5),
        ("c6", Rule::C6),
    ];
    for (name, rule) in cases {
        let rep = lint(name, Vec::new());
        assert!(!rep.findings.is_empty(), "{name}: expected at least one finding");
        for f in &rep.findings {
            assert_eq!(
                f.rule, rule,
                "{name}: unexpected {} at {}:{} — {}",
                f.rule, f.path, f.line, f.message
            );
        }
        assert!(rep.waivers.is_empty(), "{name}: unexpected waiver recorded");
    }
}

#[test]
fn fixture_findings_point_at_the_bad_lines() {
    // Spot-check locations so a lexer regression can't pass by firing the
    // right rule on the wrong line.
    let c3 = lint("c3", Vec::new());
    assert_eq!(c3.findings.len(), 1);
    assert_eq!((c3.findings[0].path.as_str(), c3.findings[0].line), ("sq/bad.rs", 5));

    let c5 = lint("c5", Vec::new());
    let lines: Vec<usize> = c5.findings.iter().map(|f| f.line).collect();
    assert_eq!(lines, vec![5, 6], "one finding per bad line, deduped per (line, rule)");

    let c6 = lint("c6", Vec::new());
    let lines: Vec<usize> = c6.findings.iter().map(|f| f.line).collect();
    assert_eq!(lines, vec![4, 6], "the raw connect, then the unguarded reader");
}

#[test]
fn waiver_suppresses_the_finding_and_is_recorded() {
    let rep = lint("waiver", Vec::new());
    assert!(
        rep.findings.is_empty(),
        "waived site must produce no findings, got {:?}",
        rep.findings
    );
    assert_eq!(
        rep.waivers,
        vec![Waiver {
            rule: Rule::C3,
            path: "stream/bad.rs".into(),
            reason: "fixture telemetry only".into(),
        }]
    );
}

#[test]
fn safety_comment_plus_allowlist_entry_passes_c4() {
    let allow = vec![("par/ok.rs".to_string(), "unsafe { *p }".to_string())];
    let rep = lint("c4ok", allow);
    assert!(rep.findings.is_empty(), "accepted unsafe shape flagged: {:?}", rep.findings);
}

#[test]
fn stale_allowlist_entry_is_an_error() {
    let allow = vec![("par/ok.rs".to_string(), "no such fragment".to_string())];
    let rep = lint("c4ok", allow);
    // The unsafe site loses its allowlist cover AND the entry is stale.
    let stale: Vec<_> = rep.findings.iter().filter(|f| f.line == 0).collect();
    assert_eq!(stale.len(), 1, "expected one stale-entry error, got {:?}", rep.findings);
    assert_eq!(stale[0].rule, Rule::C4);
    assert!(rep.findings.iter().any(|f| f.line != 0 && f.rule == Rule::C4));
}

#[test]
fn test_regions_are_exempt_from_c1_c2_c3() {
    let rep = lint("testexempt", Vec::new());
    assert!(rep.findings.is_empty(), "test-region code flagged: {:?}", rep.findings);
}
