// Fixture: a waived C3 site — the waiver suppresses the finding and is
// recorded for the inventory.
use std::time::Instant;

pub fn telemetry() -> u128 {
    // contract-allow(C3): fixture telemetry only
    let t0 = Instant::now();
    t0.elapsed().as_micros()
}
