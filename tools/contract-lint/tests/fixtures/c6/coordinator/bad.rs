// Fixture: C6 — a raw connect with no deadline, and a blocking reader
// built on a socket with no timeout guard anywhere nearby.
pub fn dial(addr: &str) -> std::io::Result<std::io::BufReader<std::net::TcpStream>> {
    let stream = std::net::TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    Ok(std::io::BufReader::new(stream))
}
