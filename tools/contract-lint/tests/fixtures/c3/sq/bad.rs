// Fixture: C3 — wall-clock read inside a numeric module.
use std::time::Instant;

pub fn solve_micros() -> u128 {
    let t0 = Instant::now();
    t0.elapsed().as_micros()
}
