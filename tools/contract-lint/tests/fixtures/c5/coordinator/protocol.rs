// Fixture: C5 — wire-decoded length cast to usize and used for an
// allocation with no bounds check anywhere nearby.
pub fn read_vec(b: &[u8]) -> Vec<u8> {
    let len = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
    let mut out = Vec::with_capacity(len as usize);
    out.extend_from_slice(&b[4..4 + len as usize]);
    out
}
