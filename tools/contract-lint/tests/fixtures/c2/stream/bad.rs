// Fixture: C2 — hash-ordered container in a numeric module; iteration
// order differs per process and leaks into the sum.
use std::collections::HashMap;

pub fn sum_in_hash_order(m: &HashMap<u64, f64>) -> f64 {
    m.values().sum()
}
