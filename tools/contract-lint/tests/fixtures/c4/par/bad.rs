// Fixture: C4 — `unsafe` with neither a safety comment nor an allowlist
// entry (findings dedupe per line, so exactly one C4 finding fires here).
pub fn read_raw(p: *const u64) -> u64 {
    unsafe { *p }
}
