// Fixture: C1 — roots a generator outside the allow-listed derivation
// sites (must derive via `Xoshiro256pp::stream(base, idx)` instead).
use crate::util::rng::Xoshiro256pp;

pub fn chunk_noise(seed: u64) -> u64 {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    rng.next_u64()
}
