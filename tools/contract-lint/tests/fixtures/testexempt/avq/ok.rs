// Fixture: test regions are exempt from C1/C2/C3 — seeding, hash maps and
// wall-clock reads are fine inside `#[cfg(test)]`.
pub fn double(x: u64) -> u64 {
    x * 2
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;
    use std::time::Instant;

    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn seeded_fixture() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let mut m = HashMap::new();
        m.insert(rng.next_u64(), Instant::now());
        assert_eq!(m.len(), 1);
    }
}
