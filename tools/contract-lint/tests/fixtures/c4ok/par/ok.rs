// Fixture: the accepted C4 shape — SAFETY comment plus allowlist entry.
pub fn read_raw(p: *const u64) -> u64 {
    // SAFETY: callers pass a pointer to a live, aligned u64 (fixture).
    unsafe { *p }
}
